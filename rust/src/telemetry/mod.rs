//! `kitsune::telemetry` — cross-layer observability: per-stage metrics,
//! ring-queue edge accounting, scheduler worker tallies, dataflow
//! traffic classification, and Chrome-trace span export.
//!
//! The paper's headline numbers are observability numbers — 41–98%
//! off-chip traffic reduction and higher utilization from dataflow
//! execution (Figs 9/13). This module is the host-level counterpart:
//!
//! - **Metrics core** — lock-free [`Counter`]s and log-bucket
//!   [`Histogram`]s (shared with `serve::stats`) recording tile
//!   queue-wait / compute / emit time per stage, push-full / pop-empty
//!   stalls and occupancy per ring-queue edge, and busy/steal/park
//!   tallies per scheduler worker. [`snapshot`] collects everything
//!   into a [`TelemetrySnapshot`]; [`prometheus`] renders the
//!   Prometheus text exposition served by the serve tier.
//! - **Traffic accounting** — every byte a pipeline moves is classified
//!   as *on-chip-analog* (crossing a ring-queue edge between resident
//!   stages — traffic the paper's dataflow execution keeps in shared
//!   memory/L2) or *off-chip-analog* (parameter reads, source
//!   injection, sink drains — traffic that hits DRAM either way).
//!   [`TrafficSnapshot::reduction`] reports the dataflow-vs-serial-
//!   oracle reduction ratio: the serial baseline pays every on-chip
//!   byte twice (producer store + consumer load to DRAM).
//! - **Trace export** — see [`trace`]: spans behind `KITSUNE_TRACE`.
//!
//! Counters are always on; the overhead discipline (< 2% warm
//! throughput, pinned by `benches/traffic_accounting.rs` as
//! `telemetry_overhead`) matches the fault harness's `fault_overhead`.

pub mod hist;
pub mod trace;

pub use hist::{Histogram, LatencySnapshot};

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, Weak};

/// A lock-free monotonically-increasing counter (relaxed ordering:
/// telemetry reads are statistical, never synchronizing).
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Raise the stored value to at least `n` (for high-water marks).
    #[inline]
    pub fn record_max(&self, n: u64) {
        self.0.fetch_max(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------
// Process-global queue counters
// ---------------------------------------------------------------------

/// Aggregate ring-queue counters across every queue in the process
/// (per-edge detail lives in [`EdgeStats`] on registered pipelines).
pub struct QueueCounters {
    /// Successful `try_push`es.
    pub pushes: Counter,
    /// Items delivered by `try_pop`/`try_pop_many`.
    pub pops: Counter,
    /// `try_push` attempts that found the ring full.
    pub full_stalls: Counter,
    /// `try_pop` attempts that found the ring empty.
    pub empty_stalls: Counter,
    /// Bounded-spin iterations burned inside blocking `push`/`pop`
    /// before the caller parks (the idle-CPU contract in
    /// `tests/idle_cpu.rs`: warm idle pipelines must not accumulate
    /// these).
    pub idle_spins: Counter,
}

/// The process-wide [`QueueCounters`] instance `queue::host` records into.
pub static QUEUE: QueueCounters = QueueCounters {
    pushes: Counter::new(),
    pops: Counter::new(),
    full_stalls: Counter::new(),
    empty_stalls: Counter::new(),
    idle_spins: Counter::new(),
};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueSnapshot {
    pub pushes: u64,
    pub pops: u64,
    pub full_stalls: u64,
    pub empty_stalls: u64,
    pub idle_spins: u64,
}

impl QueueCounters {
    pub fn snapshot(&self) -> QueueSnapshot {
        QueueSnapshot {
            pushes: self.pushes.get(),
            pops: self.pops.get(),
            full_stalls: self.full_stalls.get(),
            empty_stalls: self.empty_stalls.get(),
            idle_spins: self.idle_spins.get(),
        }
    }
}

// ---------------------------------------------------------------------
// Scheduler worker tallies
// ---------------------------------------------------------------------

/// Per-worker tallies owned by `sched::Scheduler` and updated by the
/// worker loop.
#[derive(Default)]
pub struct WorkerStats {
    /// Tasks executed (from any source).
    pub tasks: Counter,
    /// Tasks obtained by stealing from another worker's deque.
    pub steals: Counter,
    /// Times the worker gave up spinning and parked on the idle condvar.
    pub parks: Counter,
    /// Time spent inside task bodies.
    pub busy_ns: Counter,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkerSnapshot {
    pub worker: usize,
    pub tasks: u64,
    pub steals: u64,
    pub parks: u64,
    pub busy_s: f64,
}

impl WorkerStats {
    pub fn snapshot(&self, worker: usize) -> WorkerSnapshot {
        WorkerSnapshot {
            worker,
            tasks: self.tasks.get(),
            steals: self.steals.get(),
            parks: self.parks.get(),
            busy_s: self.busy_ns.get() as f64 * 1e-9,
        }
    }
}

// ---------------------------------------------------------------------
// Ring-queue edges
// ---------------------------------------------------------------------

/// How an edge's bytes are classified for traffic accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeKind {
    /// Host → first stage injection (off-chip-analog).
    Source,
    /// Stage → stage crossing between co-resident stages — the traffic
    /// dataflow execution keeps on-chip.
    Interior,
    /// Last stage → host drain (off-chip-analog).
    Sink,
}

impl EdgeKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            EdgeKind::Source => "source",
            EdgeKind::Interior => "interior",
            EdgeKind::Sink => "sink",
        }
    }
}

/// Per-edge counters, attached to one `RingQueue` at service build
/// time. Push/pop/stall counts are recorded by the queue itself; bytes
/// are recorded by the producer (which knows the tile payload size).
pub struct EdgeStats {
    pub label: String,
    pub kind: EdgeKind,
    pub capacity: usize,
    pub pushes: Counter,
    pub pops: Counter,
    /// Payload bytes pushed across this edge.
    pub bytes: Counter,
    /// `try_push` attempts that found the ring full.
    pub full_stalls: Counter,
    /// `try_pop` attempts that found the ring empty.
    pub empty_stalls: Counter,
    /// Time producers spent blocked/parked waiting for space.
    pub full_stall_ns: Counter,
    /// Time consumers spent blocked/parked waiting for items.
    pub empty_stall_ns: Counter,
    /// Sum of post-push occupancy samples (mean = depth_sum / pushes).
    pub depth_sum: Counter,
    pub max_depth: Counter,
}

impl EdgeStats {
    pub fn new(label: impl Into<String>, kind: EdgeKind, capacity: usize) -> Self {
        EdgeStats {
            label: label.into(),
            kind,
            capacity,
            pushes: Counter::new(),
            pops: Counter::new(),
            bytes: Counter::new(),
            full_stalls: Counter::new(),
            empty_stalls: Counter::new(),
            full_stall_ns: Counter::new(),
            empty_stall_ns: Counter::new(),
            depth_sum: Counter::new(),
            max_depth: Counter::new(),
        }
    }

    /// Record a post-push occupancy observation.
    #[inline]
    pub fn sample_depth(&self, depth: usize) {
        self.depth_sum.add(depth as u64);
        self.max_depth.record_max(depth as u64);
    }

    pub fn snapshot(&self) -> EdgeSnapshot {
        let pushes = self.pushes.get();
        let mean_depth =
            if pushes == 0 { 0.0 } else { self.depth_sum.get() as f64 / pushes as f64 };
        EdgeSnapshot {
            label: self.label.clone(),
            kind: self.kind,
            capacity: self.capacity,
            pushes,
            pops: self.pops.get(),
            bytes: self.bytes.get(),
            full_stalls: self.full_stalls.get(),
            empty_stalls: self.empty_stalls.get(),
            full_stall_s: self.full_stall_ns.get() as f64 * 1e-9,
            empty_stall_s: self.empty_stall_ns.get() as f64 * 1e-9,
            mean_depth,
            max_depth: self.max_depth.get(),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct EdgeSnapshot {
    pub label: String,
    pub kind: EdgeKind,
    pub capacity: usize,
    pub pushes: u64,
    pub pops: u64,
    pub bytes: u64,
    pub full_stalls: u64,
    pub empty_stalls: u64,
    pub full_stall_s: f64,
    pub empty_stall_s: f64,
    pub mean_depth: f64,
    pub max_depth: u64,
}

// ---------------------------------------------------------------------
// Stages
// ---------------------------------------------------------------------

/// Per-stage metrics: tile conservation counters plus the three
/// per-tile time histograms the paper's utilization argument needs
/// (queue-wait = input starvation, compute = useful work, emit =
/// downstream backpressure).
pub struct StageTelemetry {
    pub name: String,
    pub class: String,
    pub workers: usize,
    /// Bytes of stage parameters re-read per tile (off-chip-analog).
    pub weight_bytes_per_tile: u64,
    /// Live tiles accepted for compute.
    pub tiles_in: Counter,
    /// Live tiles emitted downstream (or to the sink).
    pub tiles_out: Counter,
    /// Episodes parked waiting for input tiles.
    pub queue_wait: Histogram,
    /// Per-tile kernel execution time.
    pub compute: Histogram,
    /// Episodes parked waiting for downstream space.
    pub emit: Histogram,
}

impl StageTelemetry {
    pub fn new(
        name: impl Into<String>,
        class: impl Into<String>,
        workers: usize,
        weight_bytes_per_tile: u64,
    ) -> Self {
        StageTelemetry {
            name: name.into(),
            class: class.into(),
            workers,
            weight_bytes_per_tile,
            tiles_in: Counter::new(),
            tiles_out: Counter::new(),
            queue_wait: Histogram::default(),
            compute: Histogram::default(),
            emit: Histogram::default(),
        }
    }

    pub fn snapshot(&self) -> StageSnapshot {
        StageSnapshot {
            name: self.name.clone(),
            class: self.class.clone(),
            workers: self.workers,
            tiles_in: self.tiles_in.get(),
            tiles_out: self.tiles_out.get(),
            queue_wait: self.queue_wait.snapshot(),
            compute: self.compute.snapshot(),
            emit: self.emit.snapshot(),
            busy_s: self.compute.sum_ns() as f64 * 1e-9,
            wait_s: (self.queue_wait.sum_ns() + self.emit.sum_ns()) as f64 * 1e-9,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct StageSnapshot {
    pub name: String,
    pub class: String,
    pub workers: usize,
    pub tiles_in: u64,
    pub tiles_out: u64,
    pub queue_wait: LatencySnapshot,
    pub compute: LatencySnapshot,
    pub emit: LatencySnapshot,
    /// Total compute time across workers.
    pub busy_s: f64,
    /// Total starvation + backpressure time across workers.
    pub wait_s: f64,
}

// ---------------------------------------------------------------------
// Traffic accounting
// ---------------------------------------------------------------------

/// Byte movement classified by locality analog. Recorded by the
/// services (which know payload sizes); edges contribute via
/// [`TrafficStats::record_edge`].
#[derive(Default)]
pub struct TrafficStats {
    /// Host → pipeline injection (off-chip-analog).
    pub source_bytes: Counter,
    /// Stage → stage ring-queue crossings (on-chip-analog).
    pub onchip_bytes: Counter,
    /// Pipeline → host drains (off-chip-analog).
    pub sink_bytes: Counter,
    /// Parameter/weight reads per tile (off-chip-analog).
    pub weight_bytes: Counter,
}

impl TrafficStats {
    #[inline]
    pub fn record_edge(&self, kind: EdgeKind, bytes: u64) {
        match kind {
            EdgeKind::Source => self.source_bytes.add(bytes),
            EdgeKind::Interior => self.onchip_bytes.add(bytes),
            EdgeKind::Sink => self.sink_bytes.add(bytes),
        }
    }

    pub fn snapshot(&self) -> TrafficSnapshot {
        TrafficSnapshot {
            source_bytes: self.source_bytes.get(),
            onchip_bytes: self.onchip_bytes.get(),
            sink_bytes: self.sink_bytes.get(),
            weight_bytes: self.weight_bytes.get(),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TrafficSnapshot {
    pub source_bytes: u64,
    pub onchip_bytes: u64,
    pub sink_bytes: u64,
    pub weight_bytes: u64,
}

impl TrafficSnapshot {
    /// Off-chip-analog bytes under dataflow execution: intermediates
    /// ride the ring queues, so only injection, drains, and parameter
    /// reads touch the DRAM analog.
    pub fn dataflow_offchip_bytes(&self) -> u64 {
        self.source_bytes + self.sink_bytes + self.weight_bytes
    }

    /// Off-chip-analog bytes for the serial oracle over the *same*
    /// tile stream: every intermediate is stored by its producer and
    /// re-loaded by its consumer, so each on-chip byte is paid twice.
    pub fn serial_offchip_bytes(&self) -> u64 {
        self.dataflow_offchip_bytes() + 2 * self.onchip_bytes
    }

    /// Fractional off-chip traffic reduction of dataflow over the
    /// serial oracle — the repo's analog of the paper's 41–98% figures.
    pub fn reduction(&self) -> f64 {
        let serial = self.serial_offchip_bytes();
        if serial == 0 {
            return 0.0;
        }
        1.0 - self.dataflow_offchip_bytes() as f64 / serial as f64
    }
}

// ---------------------------------------------------------------------
// Pipeline registry
// ---------------------------------------------------------------------

/// One pipeline's full telemetry: stages, edges, traffic. Created by
/// `PipelineService`/`TrainService` at build time and registered
/// process-wide (weakly — dropping the service unregisters it).
pub struct PipelineTelemetry {
    pub name: String,
    pub stages: Vec<StageTelemetry>,
    pub edges: Vec<Arc<EdgeStats>>,
    pub traffic: TrafficStats,
}

impl PipelineTelemetry {
    /// Build and register. The returned `Arc` is owned by the service;
    /// [`snapshot`] sees it for as long as the service lives.
    pub fn register(
        name: impl Into<String>,
        stages: Vec<StageTelemetry>,
        edges: Vec<Arc<EdgeStats>>,
    ) -> Arc<Self> {
        let p = Arc::new(PipelineTelemetry {
            name: name.into(),
            stages,
            edges,
            traffic: TrafficStats::default(),
        });
        let mut reg = registry().lock().unwrap();
        reg.retain(|w| w.strong_count() > 0);
        reg.push(Arc::downgrade(&p));
        p
    }

    pub fn snapshot(&self) -> PipelineSnapshot {
        PipelineSnapshot {
            name: self.name.clone(),
            stages: self.stages.iter().map(StageTelemetry::snapshot).collect(),
            edges: self.edges.iter().map(|e| e.snapshot()).collect(),
            traffic: self.traffic.snapshot(),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct PipelineSnapshot {
    pub name: String,
    pub stages: Vec<StageSnapshot>,
    pub edges: Vec<EdgeSnapshot>,
    pub traffic: TrafficSnapshot,
}

fn registry() -> &'static Mutex<Vec<Weak<PipelineTelemetry>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Weak<PipelineTelemetry>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

/// Point-in-time view of the whole process: queue aggregates, scheduler
/// workers, and every live registered pipeline.
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetrySnapshot {
    pub queue: QueueSnapshot,
    pub workers: Vec<WorkerSnapshot>,
    pub pipelines: Vec<PipelineSnapshot>,
}

/// Collect a [`TelemetrySnapshot`] across all layers. Cheap (relaxed
/// loads + one registry lock); never spawns the global scheduler.
pub fn snapshot() -> TelemetrySnapshot {
    let pipelines = registry()
        .lock()
        .unwrap()
        .iter()
        .filter_map(Weak::upgrade)
        .map(|p| p.snapshot())
        .collect();
    TelemetrySnapshot {
        queue: QUEUE.snapshot(),
        workers: crate::sched::worker_telemetry(),
        pipelines,
    }
}

// ---------------------------------------------------------------------
// Prometheus text exposition
// ---------------------------------------------------------------------

fn escape_label(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

impl TelemetrySnapshot {
    /// Render the snapshot in the Prometheus text exposition format
    /// (version 0.0.4) — the serve tier exposes this via
    /// `Server::prometheus`.
    pub fn prometheus(&self) -> String {
        let mut out = String::new();
        let q = &self.queue;
        out.push_str("# TYPE kitsune_queue_ops_total counter\n");
        let _ = writeln!(out, "kitsune_queue_ops_total{{op=\"push\"}} {}", q.pushes);
        let _ = writeln!(out, "kitsune_queue_ops_total{{op=\"pop\"}} {}", q.pops);
        out.push_str("# TYPE kitsune_queue_stalls_total counter\n");
        let _ = writeln!(out, "kitsune_queue_stalls_total{{kind=\"full\"}} {}", q.full_stalls);
        let _ = writeln!(out, "kitsune_queue_stalls_total{{kind=\"empty\"}} {}", q.empty_stalls);
        out.push_str("# TYPE kitsune_queue_idle_spins_total counter\n");
        let _ = writeln!(out, "kitsune_queue_idle_spins_total {}", q.idle_spins);

        out.push_str("# TYPE kitsune_worker_tasks_total counter\n");
        out.push_str("# TYPE kitsune_worker_steals_total counter\n");
        out.push_str("# TYPE kitsune_worker_parks_total counter\n");
        out.push_str("# TYPE kitsune_worker_busy_seconds_total counter\n");
        for w in &self.workers {
            let _ =
                writeln!(out, "kitsune_worker_tasks_total{{worker=\"{}\"}} {}", w.worker, w.tasks);
            let _ = writeln!(
                out,
                "kitsune_worker_steals_total{{worker=\"{}\"}} {}",
                w.worker, w.steals
            );
            let _ =
                writeln!(out, "kitsune_worker_parks_total{{worker=\"{}\"}} {}", w.worker, w.parks);
            let _ = writeln!(
                out,
                "kitsune_worker_busy_seconds_total{{worker=\"{}\"}} {:.6}",
                w.worker, w.busy_s
            );
        }

        out.push_str("# TYPE kitsune_stage_tiles_total counter\n");
        out.push_str("# TYPE kitsune_stage_seconds_total counter\n");
        out.push_str("# TYPE kitsune_stage_compute_ms summary\n");
        out.push_str("# TYPE kitsune_edge_bytes_total counter\n");
        out.push_str("# TYPE kitsune_edge_stalls_total counter\n");
        out.push_str("# TYPE kitsune_traffic_bytes_total counter\n");
        for p in &self.pipelines {
            let pl = escape_label(&p.name);
            for s in &p.stages {
                let sl = escape_label(&s.name);
                let _ = writeln!(
                    out,
                    "kitsune_stage_tiles_total{{pipeline=\"{pl}\",stage=\"{sl}\",dir=\"in\"}} {}",
                    s.tiles_in
                );
                let _ = writeln!(
                    out,
                    "kitsune_stage_tiles_total{{pipeline=\"{pl}\",stage=\"{sl}\",dir=\"out\"}} {}",
                    s.tiles_out
                );
                for (phase, secs) in [
                    ("compute", s.busy_s),
                    ("queue_wait", s.queue_wait.count as f64 * s.queue_wait.mean_ms * 1e-3),
                    ("emit", s.emit.count as f64 * s.emit.mean_ms * 1e-3),
                ] {
                    let _ = writeln!(
                        out,
                        "kitsune_stage_seconds_total{{pipeline=\"{pl}\",stage=\"{sl}\",\
                         phase=\"{phase}\"}} {secs:.6}"
                    );
                }
                for (qname, ms) in [
                    ("0.5", s.compute.p50_ms),
                    ("0.95", s.compute.p95_ms),
                    ("0.99", s.compute.p99_ms),
                ] {
                    let _ = writeln!(
                        out,
                        "kitsune_stage_compute_ms{{pipeline=\"{pl}\",stage=\"{sl}\",\
                         quantile=\"{qname}\"}} {ms:.6}"
                    );
                }
            }
            for e in &p.edges {
                let el = escape_label(&e.label);
                let _ = writeln!(
                    out,
                    "kitsune_edge_bytes_total{{pipeline=\"{pl}\",edge=\"{el}\",\
                     kind=\"{}\"}} {}",
                    e.kind.as_str(),
                    e.bytes
                );
                let _ = writeln!(
                    out,
                    "kitsune_edge_stalls_total{{pipeline=\"{pl}\",edge=\"{el}\",\
                     kind=\"full\"}} {}",
                    e.full_stalls
                );
                let _ = writeln!(
                    out,
                    "kitsune_edge_stalls_total{{pipeline=\"{pl}\",edge=\"{el}\",\
                     kind=\"empty\"}} {}",
                    e.empty_stalls
                );
            }
            let t = &p.traffic;
            for (class, bytes) in [
                ("source", t.source_bytes),
                ("onchip", t.onchip_bytes),
                ("sink", t.sink_bytes),
                ("weights", t.weight_bytes),
            ] {
                let _ = writeln!(
                    out,
                    "kitsune_traffic_bytes_total{{pipeline=\"{pl}\",class=\"{class}\"}} {bytes}"
                );
            }
        }
        out
    }
}

/// [`snapshot`] rendered as Prometheus text — one call for exporters.
pub fn prometheus() -> String {
    snapshot().prometheus()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traffic_reduction_matches_hand_computation() {
        let t = TrafficStats::default();
        t.record_edge(EdgeKind::Source, 100);
        t.record_edge(EdgeKind::Interior, 400);
        t.record_edge(EdgeKind::Sink, 50);
        t.weight_bytes.add(150);
        let s = t.snapshot();
        assert_eq!(s.dataflow_offchip_bytes(), 300);
        assert_eq!(s.serial_offchip_bytes(), 1100);
        let expect = 1.0 - 300.0 / 1100.0;
        assert!((s.reduction() - expect).abs() < 1e-12);
    }

    #[test]
    fn empty_traffic_reports_zero_reduction() {
        let s = TrafficStats::default().snapshot();
        assert_eq!(s.reduction(), 0.0);
    }

    #[test]
    fn registry_drops_dead_pipelines() {
        let p = PipelineTelemetry::register(
            "reg-test-live",
            vec![StageTelemetry::new("s0", "tensor", 1, 0)],
            vec![Arc::new(EdgeStats::new("src->s0", EdgeKind::Source, 8))],
        );
        {
            let dead = PipelineTelemetry::register("reg-test-dead", Vec::new(), Vec::new());
            drop(dead);
        }
        let snap = snapshot();
        assert!(snap.pipelines.iter().any(|x| x.name == "reg-test-live"));
        assert!(!snap.pipelines.iter().any(|x| x.name == "reg-test-dead"));
        drop(p);
    }

    #[test]
    fn prometheus_exposition_names_every_layer() {
        let p = PipelineTelemetry::register(
            "prom-test",
            vec![StageTelemetry::new("stage0", "tensor", 2, 64)],
            vec![Arc::new(EdgeStats::new("source->stage0", EdgeKind::Source, 8))],
        );
        p.stages[0].tiles_in.add(3);
        p.stages[0].compute.record(std::time::Duration::from_micros(10));
        p.traffic.record_edge(EdgeKind::Interior, 1024);
        let text = prometheus();
        for needle in [
            "kitsune_queue_ops_total{op=\"push\"}",
            "kitsune_queue_idle_spins_total",
            "kitsune_stage_tiles_total{pipeline=\"prom-test\",stage=\"stage0\",dir=\"in\"} 3",
            "kitsune_edge_bytes_total{pipeline=\"prom-test\",edge=\"source->stage0\",kind=\"source\"}",
            "kitsune_traffic_bytes_total{pipeline=\"prom-test\",class=\"onchip\"} 1024",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        drop(p);
    }
}
