//! The shared log-bucketed histogram behind every latency/duration
//! metric in the crate — serve request latency, per-stage tile
//! queue-wait/compute/emit times, and edge stall times all record into
//! this one implementation.
//!
//! The histogram uses 8 linear sub-buckets per power-of-two octave of
//! nanoseconds (HDR-style), so percentile queries are accurate to
//! ≤ 12.5% across the full ns..minutes range with a fixed 512-slot
//! atomic array — recording is two atomic adds, cheap enough to sit on
//! the per-tile hot path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Buckets: 8 exact slots for 0..8 ns, then 8 sub-buckets per octave.
const N_BUCKETS: usize = 512;

/// Lock-free duration histogram (concurrent `record`, snapshot reads).
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }
}

/// Bucket index for a nanosecond value: identity below 8, then
/// `8 + octave*8 + top-3-bits-after-the-leading-1`.
fn bucket_of(ns: u64) -> usize {
    if ns < 8 {
        return ns as usize;
    }
    let msb = 63 - ns.leading_zeros() as u64; // >= 3
    let sub = (ns >> (msb - 3)) & 0x7;
    (8 + (msb - 3) * 8 + sub) as usize
}

/// Upper bound (ns) of a bucket — the value percentile queries report.
fn bucket_upper(idx: usize) -> u64 {
    if idx < 8 {
        return idx as u64 + 1;
    }
    let o = (idx - 8) / 8;
    let sub = ((idx - 8) % 8) as u64;
    ((8 + sub) << o) + (1u64 << o)
}

impl Histogram {
    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos().min(u128::from(u64::MAX)) as u64);
    }

    pub fn record_ns(&self, ns: u64) {
        let idx = bucket_of(ns).min(N_BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Total recorded time in nanoseconds.
    pub fn sum_ns(&self) -> u64 {
        self.sum_ns.load(Ordering::Relaxed)
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns.load(Ordering::Relaxed)
    }

    /// Latency at quantile `q` in `[0, 1]`, as the upper bound of the
    /// bucket where the cumulative count crosses `q * count` (≤ 12.5%
    /// overestimate). Zero when nothing has been recorded.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let total = self.count.load(Ordering::Relaxed);
        if total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (idx, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return bucket_upper(idx);
            }
        }
        self.max_ns.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> LatencySnapshot {
        let count = self.count.load(Ordering::Relaxed);
        let mean_ns = if count == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / count as f64
        };
        LatencySnapshot {
            count,
            mean_ms: mean_ns * 1e-6,
            p50_ms: self.quantile_ns(0.50) as f64 * 1e-6,
            p95_ms: self.quantile_ns(0.95) as f64 * 1e-6,
            p99_ms: self.quantile_ns(0.99) as f64 * 1e-6,
            max_ms: self.max_ns.load(Ordering::Relaxed) as f64 * 1e-6,
        }
    }
}

/// Point-in-time percentile summary of one histogram.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySnapshot {
    pub count: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_monotone_and_cover_range() {
        let mut prev = 0u64;
        for idx in 0..N_BUCKETS {
            let up = bucket_upper(idx);
            assert!(up > prev, "bucket {idx}: {up} <= {prev}");
            prev = up;
        }
        // Round trip: a value lands in a bucket whose bound is within
        // 12.5% above it.
        for ns in [1u64, 7, 8, 100, 1_000, 55_555, 1_000_000, 123_456_789] {
            let up = bucket_upper(bucket_of(ns));
            assert!(up > ns, "{ns} -> {up}");
            assert!((up as f64) <= ns as f64 * 1.125 + 1.0, "{ns} -> {up}");
        }
    }

    #[test]
    fn quantiles_track_recorded_distribution() {
        let h = Histogram::default();
        // 90 fast (1ms) + 10 slow (100ms).
        for _ in 0..90 {
            h.record(Duration::from_millis(1));
        }
        for _ in 0..10 {
            h.record(Duration::from_millis(100));
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        assert!(s.p50_ms >= 1.0 && s.p50_ms < 1.2, "p50 {}", s.p50_ms);
        assert!(s.p99_ms >= 100.0 && s.p99_ms < 120.0, "p99 {}", s.p99_ms);
        assert!(s.max_ms >= 100.0);
        assert!(s.mean_ms > 1.0 && s.mean_ms < 100.0);
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = Histogram::default();
        assert_eq!(h.quantile_ns(0.99), 0);
        let s = h.snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.p50_ms, 0.0);
    }
}
