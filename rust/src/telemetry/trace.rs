//! Span recording behind the `KITSUNE_TRACE=<path>` knob, exported as
//! Chrome-trace / Perfetto JSON (`chrome://tracing`, <https://ui.perfetto.dev>).
//!
//! When disabled (the default) every record call is one atomic load and
//! a branch — cheap enough to leave in the per-tile hot path. When a
//! trace path is armed (env knob or [`enable`] from the `kitsune trace`
//! CLI), spans are buffered in memory and written on [`flush`]: one
//! track per thread (scheduler workers keep their `kitsune-sched-N`
//! names, so stage pumps show up on the worker that ran them), the
//! stage/event name on the span, and the tile sequence number in
//! `args`. The env knob follows the crate-wide warn-once policy
//! ([`crate::sched::warn_env_once`]): a set-but-empty path warns once
//! and disables tracing rather than erroring.

use std::cell::Cell;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Soft cap on buffered spans: beyond this the recorder drops (and
/// counts) events instead of growing without bound on long runs.
const MAX_EVENTS: usize = 1 << 20;

struct Event {
    tid: u64,
    /// Span name — the stage or phase that ran.
    name: String,
    /// Category: "compute", "step", "dispatch", ...
    cat: &'static str,
    /// Tile sequence number, when the span covers one tile.
    tile: Option<u64>,
    ts_ns: u64,
    dur_ns: u64,
}

#[derive(Default)]
struct State {
    events: Vec<Event>,
    /// (tid, thread name) pairs, registered on a thread's first span.
    threads: Vec<(u64, String)>,
    dropped: u64,
}

struct Sink {
    path: PathBuf,
    epoch: Instant,
    state: Mutex<State>,
}

static SINK: OnceLock<Option<Sink>> = OnceLock::new();

fn sink() -> Option<&'static Sink> {
    SINK.get_or_init(|| {
        let raw = std::env::var("KITSUNE_TRACE").ok()?;
        if raw.trim().is_empty() {
            crate::sched::warn_env_once(
                "KITSUNE_TRACE",
                "kitsune: KITSUNE_TRACE is set but empty; tracing disabled",
            );
            return None;
        }
        Some(Sink { path: PathBuf::from(raw), epoch: Instant::now(), state: Mutex::default() })
    })
    .as_ref()
}

/// Arm tracing programmatically (the `kitsune trace` CLI path). Must be
/// called before the first span is recorded — the sink latches on first
/// use, so a later `enable` cannot redirect it. Returns the path
/// actually in effect (the env knob wins if it latched first), or
/// `None` if tracing was already latched off.
pub fn enable(path: &Path) -> Option<PathBuf> {
    let sink = SINK.get_or_init(|| {
        Some(Sink {
            path: path.to_path_buf(),
            epoch: Instant::now(),
            state: Mutex::default(),
        })
    });
    sink.as_ref().map(|s| s.path.clone())
}

/// True when a trace path is armed (env knob or [`enable`]).
pub fn enabled() -> bool {
    sink().is_some()
}

thread_local! {
    static TID: Cell<Option<u64>> = const { Cell::new(None) };
}

/// The calling thread's stable trace track id, registering the thread's
/// name with the sink on first use.
fn thread_tid(state: &mut State) -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    TID.with(|slot| match slot.get() {
        Some(tid) => tid,
        None => {
            let tid = NEXT.fetch_add(1, Ordering::Relaxed);
            slot.set(Some(tid));
            let name = std::thread::current()
                .name()
                .map(str::to_string)
                .unwrap_or_else(|| format!("thread-{tid}"));
            state.threads.push((tid, name));
            tid
        }
    })
}

/// Record a completed span that started at `start` on this thread. A
/// no-op (one atomic load) when tracing is disabled.
pub fn span(cat: &'static str, name: &str, tile: Option<u64>, start: Instant) {
    let Some(s) = sink() else { return };
    let dur_ns = start.elapsed().as_nanos().min(u128::from(u64::MAX)) as u64;
    let since_epoch = start.saturating_duration_since(s.epoch);
    let ts_ns = since_epoch.as_nanos().min(u128::from(u64::MAX)) as u64;
    let mut state = s.state.lock().unwrap();
    if state.events.len() >= MAX_EVENTS {
        state.dropped += 1;
        return;
    }
    let tid = thread_tid(&mut state);
    state.events.push(Event { tid, name: name.to_string(), cat, tile, ts_ns, dur_ns });
}

fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Write the buffered trace to the armed path as Chrome-trace JSON.
/// Returns the path written, or `None` when tracing is disabled. The
/// buffer is kept (not drained), so repeated flushes rewrite a complete
/// file each time.
pub fn flush() -> std::io::Result<Option<PathBuf>> {
    let Some(s) = sink() else { return Ok(None) };
    let state = s.state.lock().unwrap();
    use std::fmt::Write as _;
    let mut json = String::from("{\"traceEvents\": [\n");
    let mut first = true;
    for (tid, name) in &state.threads {
        if !first {
            json.push_str(",\n");
        }
        first = false;
        let _ = write!(
            json,
            "  {{\"ph\": \"M\", \"pid\": 1, \"tid\": {tid}, \"name\": \"thread_name\", \
             \"args\": {{\"name\": \"{}\"}}}}",
            escape_json(name)
        );
    }
    for e in &state.events {
        if !first {
            json.push_str(",\n");
        }
        first = false;
        let _ = write!(
            json,
            "  {{\"ph\": \"X\", \"pid\": 1, \"tid\": {}, \"name\": \"{}\", \"cat\": \"{}\", \
             \"ts\": {:.3}, \"dur\": {:.3}, \"args\": {{",
            e.tid,
            escape_json(&e.name),
            e.cat,
            e.ts_ns as f64 / 1e3,
            e.dur_ns as f64 / 1e3,
        );
        if let Some(tile) = e.tile {
            let _ = write!(json, "\"tile\": {tile}");
        }
        json.push_str("}}");
    }
    let _ = write!(
        json,
        "\n], \"displayTimeUnit\": \"ms\", \"otherData\": {{\"dropped_events\": {}}}}}\n",
        state.dropped
    );
    drop(state);
    std::fs::write(&s.path, json)?;
    Ok(Some(s.path.clone()))
}
