//! Fault tolerance for long-lived spatial pipelines: typed stage
//! failures, poison-tile propagation, pipeline health, and a
//! deterministic fault-injection harness.
//!
//! Kitsune's persistent pipelines turn one bad kernel launch into a
//! poisoned *resident* structure: a panicking stage used to unwind into
//! the scheduler, strand the in-flight table, and wedge every request
//! queued behind it. This module makes failure a first-class value
//! instead:
//!
//! * [`StageFailure`] — the one typed failure record produced everywhere
//!   a stage program runs (session pumps, DAG training pumps, serial
//!   oracles, fork-join GEMM panels). Built by [`catch_stage`], which
//!   fences every stage execution with `catch_unwind`.
//! * [`Envelope`] — the item type flowing through
//!   [`crate::queue::RingQueue`] edges: `Ok(tile)` or
//!   `Poison(StageFailure)`. Downstream stages forward poison without
//!   computing, so exactly the afflicted ticket/step fails while
//!   unrelated in-flight tiles complete — the pipeline degrades
//!   per-tile, not per-process.
//! * [`Health`] / [`HealthState`] — the `Healthy → Degraded → Failed`
//!   state machine a supervised pipeline publishes; the serving tier
//!   consults it to retry or shed admitted requests.
//! * [`RestartPolicy`] — bounded stage-restart budget with exponential
//!   backoff, used by the session supervisor when it respawns a failed
//!   pump.
//! * [`FaultPlan`] — the deterministic injection harness behind the
//!   `KITSUNE_FAULT` environment knob (grammar below) and the
//!   programmatic [`crate::session::SessionBuilder::fault_plan`] hook.
//!   Every armed fault fires exactly once, at a fixed stage/tile/step,
//!   so chaos tests are reproducible in CI rather than flaky.
//!
//! # `KITSUNE_FAULT` grammar
//!
//! Comma- or semicolon-separated specs, parsed once per process with
//! the same warn-once policy as the `KITSUNE_*` scheduler knobs
//! (see [`crate::sched::env_usize`]):
//!
//! ```text
//! panic:stage=2:tile=7     # stage 2's pump panics on its 8th tile (0-based)
//! nan:loss:step=3          # training step 3 folds a NaN loss
//! nan:grad:step=3          # training step 3 produces a NaN gradient
//! queue_close:edge=1       # pipeline edge queue 1 is closed at startup
//! ```

use std::any::Any;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

/// Why a stage execution failed. All payloads are pre-rendered strings
/// so the whole failure record stays `Clone + Eq` and can cross queue
/// edges, ticket tables and the `anyhow` downcast boundary untouched.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FailureCause {
    /// The stage program panicked; payload is the panic message.
    Panic(String),
    /// The stage program returned a kernel/runtime error.
    Kernel(String),
    /// The stage produced a non-finite value (NaN/Inf loss or gradient).
    NonFinite {
        /// What was non-finite, e.g. `"loss"` or `"grad mlp/w0"`.
        what: String,
    },
    /// A queue edge the stage depends on closed mid-flight (shutdown or
    /// a torn-down neighbor).
    QueueClosed,
}

/// A typed record of one stage failure: which stage died, on which tile
/// (when known), and why. This is what poison tiles carry, what tickets
/// and training steps resolve with (via
/// [`crate::runtime::RuntimeError::StageFailed`]), and what the health
/// machine logs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageFailure {
    /// Artifact entry / stage name (e.g. `"nerf_trunk_stage1_fwd"`).
    pub stage: String,
    /// Position in the pipeline, when the stage knows it.
    pub stage_index: Option<usize>,
    /// Per-stage tile sequence number the failure struck at, when known.
    pub tile_seq: Option<u64>,
    pub cause: FailureCause,
}

impl StageFailure {
    pub fn new(stage: impl Into<String>, cause: FailureCause) -> Self {
        StageFailure { stage: stage.into(), stage_index: None, tile_seq: None, cause }
    }

    /// Tag the failure with its pipeline stage index.
    pub fn at_index(mut self, si: usize) -> Self {
        self.stage_index = Some(si);
        self
    }

    /// Tag the failure with the per-stage tile sequence it struck at.
    pub fn at_tile(mut self, seq: u64) -> Self {
        self.tile_seq = Some(seq);
        self
    }

    /// A shutdown/teardown failure: the queue edge under `stage` closed
    /// before the tile could be delivered.
    pub fn closed(stage: impl Into<String>) -> Self {
        StageFailure::new(stage, FailureCause::QueueClosed)
    }

    /// Wrap into the crate error type (downcastable to
    /// [`crate::runtime::RuntimeError::StageFailed`]).
    pub fn into_error(self) -> anyhow::Error {
        crate::runtime::RuntimeError::StageFailed(self).into()
    }
}

impl std::fmt::Display for StageFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "stage '{}'", self.stage)?;
        if let Some(si) = self.stage_index {
            write!(f, " (index {si})")?;
        }
        if let Some(seq) = self.tile_seq {
            write!(f, " at tile {seq}")?;
        }
        match &self.cause {
            FailureCause::Panic(msg) => write!(f, " panicked: {msg}"),
            FailureCause::Kernel(msg) => write!(f, " failed: {msg}"),
            FailureCause::NonFinite { what } => write!(f, " produced non-finite {what}"),
            // Keep "shut down" in this rendering: callers assert on it
            // to distinguish orderly teardown from stage faults.
            FailureCause::QueueClosed => write!(f, ": pipeline shut down mid-flight"),
        }
    }
}

impl std::error::Error for StageFailure {}

/// Render a panic payload (from `catch_unwind`) as a string. `panic!`
/// with a format string yields `String`; `panic!("literal")` yields
/// `&'static str`; anything else is opaque.
pub fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run one stage program execution inside a panic fence. Panics become
/// [`FailureCause::Panic`], `Err` returns become [`FailureCause::Kernel`]
/// — either way the caller gets a typed [`StageFailure`] instead of an
/// unwind into the scheduler.
///
/// `AssertUnwindSafe` is sound here because every caller either owns its
/// inputs or re-reads shared state (weights, artifact store) fresh on
/// the next tile — a half-updated local buffer dies with the closure.
pub fn catch_stage<T>(
    stage: &str,
    stage_index: Option<usize>,
    tile_seq: Option<u64>,
    f: impl FnOnce() -> anyhow::Result<T>,
) -> Result<T, StageFailure> {
    let fail = |cause| StageFailure { stage: stage.to_string(), stage_index, tile_seq, cause };
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(Ok(v)) => Ok(v),
        Ok(Err(e)) => Err(fail(FailureCause::Kernel(format!("{e:#}")))),
        Err(payload) => Err(fail(FailureCause::Panic(panic_message(payload.as_ref())))),
    }
}

/// The item type on every supervised queue edge: a live tile, or the
/// failure that consumed it. Poison keeps the edge's sequence space
/// dense — multicast and skip edges forward it like any other item, so
/// seq-aligned consumers never desynchronize around a failure.
#[derive(Debug, Clone, PartialEq)]
pub enum Envelope<T> {
    Ok(T),
    Poison(StageFailure),
}

impl<T> Envelope<T> {
    pub fn is_poison(&self) -> bool {
        matches!(self, Envelope::Poison(_))
    }
}

/// Pipeline health as published by a supervised service.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Health {
    /// All stages live.
    Healthy,
    /// `stage` failed and is being restarted; in-flight work on it fails
    /// typed, new work queues behind the restart.
    Degraded { stage: String },
    /// `stage` exhausted its restart budget (or a structural edge died);
    /// the pipeline completes what it can and fails the rest. Terminal.
    Failed { stage: String },
}

impl Health {
    pub fn is_healthy(&self) -> bool {
        matches!(self, Health::Healthy)
    }
}

struct HealthInner {
    health: Health,
    restarts: u64,
    failures: u64,
}

/// Shared, thread-safe holder for a pipeline's [`Health`], with restart
/// and failure counters for observability. Transitions:
/// `Healthy → Degraded` ([`HealthState::degrade`]), `Degraded → Healthy`
/// ([`HealthState::restore`], counted as one restart), `* → Failed`
/// ([`HealthState::fail`], terminal — later transitions are ignored).
pub struct HealthState {
    inner: Mutex<HealthInner>,
}

impl Default for HealthState {
    fn default() -> Self {
        HealthState {
            inner: Mutex::new(HealthInner { health: Health::Healthy, restarts: 0, failures: 0 }),
        }
    }
}

impl HealthState {
    pub fn snapshot(&self) -> Health {
        self.inner.lock().unwrap().health.clone()
    }

    /// Record a stage failure: `Healthy`/`Degraded` become
    /// `Degraded { stage }`; `Failed` is sticky.
    pub fn degrade(&self, stage: &str) {
        let mut g = self.inner.lock().unwrap();
        g.failures += 1;
        if !matches!(g.health, Health::Failed { .. }) {
            g.health = Health::Degraded { stage: stage.to_string() };
        }
    }

    /// A restarted stage came back: `Degraded → Healthy` (counted);
    /// other states unchanged.
    pub fn restore(&self) {
        let mut g = self.inner.lock().unwrap();
        if matches!(g.health, Health::Degraded { .. }) {
            g.health = Health::Healthy;
            g.restarts += 1;
        }
    }

    /// Terminal failure: the restart budget is spent or the pipeline
    /// structure itself died.
    pub fn fail(&self, stage: &str) {
        let mut g = self.inner.lock().unwrap();
        if !matches!(g.health, Health::Failed { .. }) {
            g.health = Health::Failed { stage: stage.to_string() };
        }
    }

    /// Stage restarts completed over this pipeline's lifetime.
    pub fn restarts(&self) -> u64 {
        self.inner.lock().unwrap().restarts
    }

    /// Stage failures observed (including ones later recovered).
    pub fn failures(&self) -> u64 {
        self.inner.lock().unwrap().failures
    }
}

/// Bounded-retry stage restart policy with exponential backoff.
#[derive(Debug, Clone)]
pub struct RestartPolicy {
    /// Restarts allowed per stage before the pipeline goes `Failed`
    /// (`KITSUNE_STAGE_RESTARTS`, default 2, min 1).
    pub max_restarts: usize,
    /// First-restart delay; doubles per attempt.
    pub base_backoff: Duration,
    /// Backoff ceiling.
    pub max_backoff: Duration,
}

impl RestartPolicy {
    pub fn from_env() -> Self {
        RestartPolicy {
            max_restarts: crate::sched::env_usize("KITSUNE_STAGE_RESTARTS", 2, 64),
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(50),
        }
    }

    /// Delay before restart `attempt` (0-based): `base * 2^attempt`,
    /// capped at `max_backoff`.
    pub fn backoff(&self, attempt: usize) -> Duration {
        let mult = 1u32 << attempt.min(16) as u32;
        self.base_backoff.saturating_mul(mult).min(self.max_backoff)
    }
}

/// One deterministic fault to inject.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultSpec {
    /// Panic inside stage `stage`'s program on its `tile`-th execution
    /// (0-based, counted per stage).
    Panic { stage: usize, tile: u64 },
    /// Replace training step `step`'s folded loss with NaN (0-based).
    NanLoss { step: u64 },
    /// Corrupt the first gradient of training step `step` with NaN.
    NanGrad { step: u64 },
    /// Close pipeline edge queue `edge` at service startup.
    QueueClose { edge: usize },
}

struct ArmedSpec {
    spec: FaultSpec,
    /// One-shot: flipped false by whichever execution matches first, so
    /// a restarted stage does not re-trip the same fault.
    armed: AtomicBool,
}

/// A set of armed [`FaultSpec`]s consulted at fixed points in the
/// runtime (stage compute, loss fold, gradient fold, service startup).
/// Each spec fires exactly once; an empty plan is free on the hot path
/// (one branch on a plan that is almost always [`FaultPlan::is_empty`]).
#[derive(Default)]
pub struct FaultPlan {
    specs: Vec<ArmedSpec>,
}

impl std::fmt::Debug for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_list().entries(self.specs.iter().map(|a| &a.spec)).finish()
    }
}

impl FaultPlan {
    pub fn new() -> Self {
        FaultPlan::default()
    }

    pub fn from_specs(specs: Vec<FaultSpec>) -> Self {
        FaultPlan {
            specs: specs
                .into_iter()
                .map(|spec| ArmedSpec { spec, armed: AtomicBool::new(true) })
                .collect(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Builder: arm a stage panic.
    pub fn panic_at(mut self, stage: usize, tile: u64) -> Self {
        self.specs
            .push(ArmedSpec { spec: FaultSpec::Panic { stage, tile }, armed: AtomicBool::new(true) });
        self
    }

    /// Builder: arm a NaN loss at `step`.
    pub fn nan_loss(mut self, step: u64) -> Self {
        self.specs
            .push(ArmedSpec { spec: FaultSpec::NanLoss { step }, armed: AtomicBool::new(true) });
        self
    }

    /// Builder: arm a NaN gradient at `step`.
    pub fn nan_grad(mut self, step: u64) -> Self {
        self.specs
            .push(ArmedSpec { spec: FaultSpec::NanGrad { step }, armed: AtomicBool::new(true) });
        self
    }

    /// Builder: arm an edge-queue close at startup.
    pub fn queue_close(mut self, edge: usize) -> Self {
        self.specs
            .push(ArmedSpec { spec: FaultSpec::QueueClose { edge }, armed: AtomicBool::new(true) });
        self
    }

    fn take(&self, want: &FaultSpec) -> bool {
        self.specs.iter().any(|a| {
            a.spec == *want
                && a.armed.compare_exchange(true, false, Ordering::AcqRel, Ordering::Relaxed).is_ok()
        })
    }

    /// Consume an armed panic for (`stage`, `tile`), if any.
    pub fn take_panic(&self, stage: usize, tile: u64) -> bool {
        !self.is_empty() && self.take(&FaultSpec::Panic { stage, tile })
    }

    /// Panic if a panic fault is armed for this (stage, tile). The
    /// message names the injection site so tests can assert on it.
    pub fn maybe_panic(&self, stage: usize, tile: u64) {
        if self.take_panic(stage, tile) {
            panic!("injected fault: panic at stage {stage} tile {tile}");
        }
    }

    /// Consume an armed NaN-loss for `step`, if any.
    pub fn take_nan_loss(&self, step: u64) -> bool {
        !self.is_empty() && self.take(&FaultSpec::NanLoss { step })
    }

    /// Consume an armed NaN-gradient for `step`, if any.
    pub fn take_nan_grad(&self, step: u64) -> bool {
        !self.is_empty() && self.take(&FaultSpec::NanGrad { step })
    }

    /// Consume every armed edge-close spec (called once at service
    /// startup); returns the edge indices to close.
    pub fn take_queue_closes(&self) -> Vec<usize> {
        self.specs
            .iter()
            .filter_map(|a| match a.spec {
                FaultSpec::QueueClose { edge }
                    if a.armed
                        .compare_exchange(true, false, Ordering::AcqRel, Ordering::Relaxed)
                        .is_ok() =>
                {
                    Some(edge)
                }
                _ => None,
            })
            .collect()
    }

    /// Parse a `KITSUNE_FAULT` string (see module docs for the
    /// grammar). Whole-string parse: one malformed spec rejects the
    /// plan, so a typo cannot silently drop half a chaos scenario.
    pub fn parse(raw: &str) -> Result<FaultPlan, String> {
        let mut specs = Vec::new();
        for part in raw.split([',', ';']).map(str::trim).filter(|s| !s.is_empty()) {
            let spec = parse_spec(part).ok_or_else(|| {
                format!(
                    "bad fault spec {part:?} (expected panic:stage=N:tile=M, \
                     nan:loss:step=S, nan:grad:step=S, or queue_close:edge=K)"
                )
            })?;
            specs.push(spec);
        }
        Ok(FaultPlan::from_specs(specs))
    }

    /// The process-wide plan from `KITSUNE_FAULT`, parsed once. Unset or
    /// malformed (warns once, same policy as the scheduler's env knobs)
    /// yields an empty plan.
    pub fn from_env() -> Arc<FaultPlan> {
        static PLAN: OnceLock<Arc<FaultPlan>> = OnceLock::new();
        Arc::clone(PLAN.get_or_init(|| {
            let raw = match std::env::var("KITSUNE_FAULT") {
                Ok(raw) => raw,
                Err(_) => return Arc::new(FaultPlan::default()),
            };
            match FaultPlan::parse(&raw) {
                Ok(plan) => Arc::new(plan),
                Err(msg) => {
                    crate::sched::warn_env_once(
                        "KITSUNE_FAULT",
                        &format!(
                            "kitsune: ignoring KITSUNE_FAULT={raw:?}: {msg}; \
                             no faults will be injected"
                        ),
                    );
                    Arc::new(FaultPlan::default())
                }
            }
        }))
    }
}

fn parse_kv(s: &str, key: &str) -> Option<u64> {
    let (k, v) = s.split_once('=')?;
    if k != key {
        return None;
    }
    v.parse().ok()
}

fn parse_spec(s: &str) -> Option<FaultSpec> {
    let fields: Vec<&str> = s.split(':').collect();
    match fields.as_slice() {
        ["panic", a, b] => Some(FaultSpec::Panic {
            stage: parse_kv(a, "stage")? as usize,
            tile: parse_kv(b, "tile")?,
        }),
        ["nan", "loss", a] => Some(FaultSpec::NanLoss { step: parse_kv(a, "step")? }),
        ["nan", "grad", a] => Some(FaultSpec::NanGrad { step: parse_kv(a, "step")? }),
        ["queue_close", a] => Some(FaultSpec::QueueClose { edge: parse_kv(a, "edge")? as usize }),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let plan =
            FaultPlan::parse("panic:stage=2:tile=7, nan:loss:step=3; nan:grad:step=0,queue_close:edge=1")
                .unwrap();
        assert!(plan.take_panic(2, 7));
        assert!(plan.take_nan_loss(3));
        assert!(plan.take_nan_grad(0));
        assert_eq!(plan.take_queue_closes(), vec![1]);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "panic:stage=2",          // missing tile
            "panic:tile=7:stage=2",   // wrong field order
            "nan:loss:step=x",        // non-numeric
            "queue_close:1",          // missing key
            "panik:stage=0:tile=0",   // unknown kind
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} should not parse");
        }
        // Empty string is a valid empty plan.
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn faults_fire_exactly_once() {
        let plan = FaultPlan::new().panic_at(1, 4).nan_loss(2);
        assert!(!plan.take_panic(1, 3), "wrong tile does not fire");
        assert!(!plan.take_panic(0, 4), "wrong stage does not fire");
        assert!(plan.take_panic(1, 4));
        assert!(!plan.take_panic(1, 4), "one-shot");
        assert!(plan.take_nan_loss(2));
        assert!(!plan.take_nan_loss(2));
    }

    #[test]
    fn catch_stage_converts_panics_and_errors() {
        let ok = catch_stage("s", Some(0), Some(1), || Ok(42));
        assert_eq!(ok.unwrap(), 42);

        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {})); // silence the injected panic
        let p = catch_stage::<()>("s", Some(2), Some(7), || panic!("kaboom {}", 9));
        std::panic::set_hook(hook);
        let f = p.unwrap_err();
        assert_eq!(f.stage_index, Some(2));
        assert_eq!(f.tile_seq, Some(7));
        assert_eq!(f.cause, FailureCause::Panic("kaboom 9".into()));
        assert!(f.to_string().contains("panicked: kaboom 9"), "{f}");

        let k = catch_stage::<()>("s", None, None, || Err(anyhow::anyhow!("bad kernel")));
        match k.unwrap_err().cause {
            FailureCause::Kernel(msg) => assert!(msg.contains("bad kernel")),
            other => panic!("expected Kernel, got {other:?}"),
        }
    }

    #[test]
    fn queue_closed_display_mentions_shutdown() {
        // The session stress tests distinguish orderly teardown by this
        // substring; keep it stable.
        let f = StageFailure::closed("stage3").at_index(3);
        assert!(f.to_string().contains("shut down"), "{f}");
    }

    #[test]
    fn health_machine_transitions() {
        let h = HealthState::default();
        assert!(h.snapshot().is_healthy());
        h.degrade("s1");
        assert_eq!(h.snapshot(), Health::Degraded { stage: "s1".into() });
        h.restore();
        assert!(h.snapshot().is_healthy());
        assert_eq!(h.restarts(), 1);
        assert_eq!(h.failures(), 1);
        // restore without degrade is a no-op
        h.restore();
        assert_eq!(h.restarts(), 1);
        h.fail("s2");
        assert_eq!(h.snapshot(), Health::Failed { stage: "s2".into() });
        // Failed is terminal.
        h.degrade("s3");
        h.restore();
        assert_eq!(h.snapshot(), Health::Failed { stage: "s2".into() });
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let p = RestartPolicy {
            max_restarts: 3,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(50),
        };
        assert_eq!(p.backoff(0), Duration::from_millis(1));
        assert_eq!(p.backoff(1), Duration::from_millis(2));
        assert_eq!(p.backoff(5), Duration::from_millis(32));
        assert_eq!(p.backoff(6), Duration::from_millis(50), "capped");
        assert_eq!(p.backoff(60), Duration::from_millis(50), "shift clamped");
    }

    #[test]
    fn envelope_poison_round_trip() {
        let e: Envelope<u32> = Envelope::Poison(StageFailure::new(
            "s",
            FailureCause::NonFinite { what: "loss".into() },
        ));
        assert!(e.is_poison());
        let c = e.clone();
        assert_eq!(e, c);
        assert!(!Envelope::Ok(1u32).is_poison());
    }
}
