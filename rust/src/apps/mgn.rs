//! MeshGraphNets (Pfaff et al., 2020) — "Mesh based physical simulation"
//! (paper Table 1). Encode-process-decode GNN: node/edge encoder MLPs,
//! `n_blocks` of message passing (edge MLP over gathered endpoints,
//! scatter-aggregate, node MLP, residual adds), and a decoder MLP.
//! The gather/scatter aggregation ops are excluded from sf-nodes (§5.1),
//! which is why MGN's coverage is ~80% rather than 100% (Table 2).

use crate::graph::{training_graph, AutodiffOptions, EwKind, Graph, GraphBuilder, GraphKind, NodeId, OpKind, TensorDesc};

/// Model configuration (cylinder-flow scale).
#[derive(Debug, Clone)]
pub struct MgnConfig {
    pub n_nodes: usize,
    pub n_edges: usize,
    pub node_feat: usize,
    pub edge_feat: usize,
    pub latent: usize,
    pub n_blocks: usize,
    pub out_feat: usize,
}

impl Default for MgnConfig {
    fn default() -> Self {
        MgnConfig {
            n_nodes: 8192,
            n_edges: 24576,
            node_feat: 12,
            edge_feat: 7,
            latent: 128,
            n_blocks: 3,
            out_feat: 3,
        }
    }
}

/// Forward (inference) graph.
pub fn inference(cfg: &MgnConfig) -> Graph {
    build(cfg, false)
}

/// Training graph.
pub fn training(cfg: &MgnConfig) -> Graph {
    let fwd = build(cfg, true);
    training_graph(&fwd, AutodiffOptions::default())
}

/// Two-layer MLP with LayerNorm output, the MGN building block.
fn mlp_ln(b: &mut GraphBuilder, x: NodeId, latent: usize, name: &str) -> NodeId {
    let h = b.linear(x, latent, true, &format!("{name}.0"));
    let h = b.relu(h, &format!("{name}.relu"));
    let h = b.linear(h, latent, true, &format!("{name}.1"));
    b.layernorm(h, &format!("{name}.ln"))
}

fn build(cfg: &MgnConfig, with_loss: bool) -> Graph {
    let mut b = GraphBuilder::new("mgn", GraphKind::Inference);
    let nodes_in = b.input(&[cfg.n_nodes, cfg.node_feat], "node_feats");
    let edges_in = b.input(&[cfg.n_edges, cfg.edge_feat], "edge_feats");

    // Encoders.
    let mut v = mlp_ln(&mut b, nodes_in, cfg.latent, "enc.node");
    let mut e = mlp_ln(&mut b, edges_in, cfg.latent, "enc.edge");

    // Message-passing blocks.
    for blk in 0..cfg.n_blocks {
        // Gather endpoint node latents onto edges (indexing op — excluded).
        let sender = {
            let out = TensorDesc::bf16(&[cfg.n_edges, cfg.latent]);
            b.g.add(OpKind::Gather { table_rows: cfg.n_nodes }, &[v], out, format!("mp{blk}.gather"))
        };
        let eincat = b.concat(&[e, sender], &format!("mp{blk}.edge_cat"));
        let e_new = mlp_ln(&mut b, eincat, cfg.latent, &format!("mp{blk}.edge_mlp"));
        e = b.ew2(EwKind::Add, e, e_new, &format!("mp{blk}.edge_res"));
        // Scatter-aggregate edge messages to nodes (excluded).
        let agg = {
            let out = TensorDesc::bf16(&[cfg.n_nodes, cfg.latent]);
            b.g.add(OpKind::Scatter, &[e], out, format!("mp{blk}.scatter"))
        };
        let vincat = b.concat(&[v, agg], &format!("mp{blk}.node_cat"));
        let v_new = mlp_ln(&mut b, vincat, cfg.latent, &format!("mp{blk}.node_mlp"));
        v = b.ew2(EwKind::Add, v, v_new, &format!("mp{blk}.node_res"));
    }

    // Decoder.
    let h = b.linear(v, cfg.latent, true, "dec.0");
    let h = b.relu(h, "dec.relu");
    let out = b.linear(h, cfg.out_feat, true, "dec.1");
    if with_loss {
        b.loss(out, "mse_loss");
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inference_op_count_near_paper() {
        // Paper Table 2: MGN inference has 51 ops.
        let g = inference(&MgnConfig::default());
        let n = g.n_compute_ops();
        assert!((45..=60).contains(&n), "MGN inference ops = {n}");
        assert!(g.validate().is_empty());
    }

    #[test]
    fn training_op_count_near_paper() {
        // Paper Table 2: MGN training has 148 ops.
        let g = training(&MgnConfig::default());
        let n = g.n_compute_ops();
        assert!((120..=175).contains(&n), "MGN training ops = {n}");
    }

    #[test]
    fn has_gather_scatter_breaks() {
        let g = inference(&MgnConfig::default());
        let excluded = g.compute_nodes().filter(|n| n.op.excluded_from_subgraphs()).count();
        // One gather + one scatter per message-passing block.
        assert_eq!(excluded, 2 * MgnConfig::default().n_blocks);
    }
}
