//! GraphCast (Lam et al., 2022) — "Weather forecast prediction" (paper
//! Table 1). Encode-process-decode GNN on the icosahedral mesh with a
//! wide latent (512) and a deep processor. Like MGN but with larger
//! latents and more blocks; scatter aggregations break fusion, giving the
//! ~83% inference coverage Table 2 reports for GRC.

use crate::graph::{training_graph, AutodiffOptions, EwKind, Graph, GraphBuilder, GraphKind, NodeId, OpKind, TensorDesc};

/// Model configuration (scaled-down mesh for simulation tractability;
/// latent width matches the real model).
#[derive(Debug, Clone)]
pub struct GraphCastConfig {
    pub mesh_nodes: usize,
    pub mesh_edges: usize,
    pub in_feat: usize,
    pub latent: usize,
    pub n_blocks: usize,
    pub out_feat: usize,
}

impl Default for GraphCastConfig {
    fn default() -> Self {
        GraphCastConfig {
            mesh_nodes: 10242, // icosahedron refinement 5
            mesh_edges: 30720,
            in_feat: 186,
            latent: 512,
            n_blocks: 2,
            out_feat: 83,
        }
    }
}

/// Forward (inference) graph.
pub fn inference(cfg: &GraphCastConfig) -> Graph {
    build(cfg, false)
}

/// Training graph.
pub fn training(cfg: &GraphCastConfig) -> Graph {
    let fwd = build(cfg, true);
    training_graph(&fwd, AutodiffOptions::default())
}

fn swish_mlp(b: &mut GraphBuilder, x: NodeId, latent: usize, name: &str) -> NodeId {
    let h = b.linear(x, latent, true, &format!("{name}.0"));
    let h = b.ew1(EwKind::Silu, h, &format!("{name}.swish"));
    let h = b.linear(h, latent, true, &format!("{name}.1"));
    b.layernorm(h, &format!("{name}.ln"))
}

fn build(cfg: &GraphCastConfig, with_loss: bool) -> Graph {
    let mut b = GraphBuilder::new("graphcast", GraphKind::Inference);
    let grid = b.input(&[cfg.mesh_nodes, cfg.in_feat], "grid_feats");

    // Grid→mesh encoder.
    let mut v = swish_mlp(&mut b, grid, cfg.latent, "enc");

    // Processor: message passing on the mesh.
    for blk in 0..cfg.n_blocks {
        let gathered = {
            let out = TensorDesc::bf16(&[cfg.mesh_edges, cfg.latent]);
            b.g.add(OpKind::Gather { table_rows: cfg.mesh_nodes }, &[v], out, format!("proc{blk}.gather"))
        };
        let msg = swish_mlp(&mut b, gathered, cfg.latent, &format!("proc{blk}.edge_mlp"));
        let agg = {
            let out = TensorDesc::bf16(&[cfg.mesh_nodes, cfg.latent]);
            b.g.add(OpKind::Scatter, &[msg], out, format!("proc{blk}.scatter"))
        };
        let cat = b.concat(&[v, agg], &format!("proc{blk}.cat"));
        let v_new = swish_mlp(&mut b, cat, cfg.latent, &format!("proc{blk}.node_mlp"));
        v = b.ew2(EwKind::Add, v, v_new, &format!("proc{blk}.res"));
    }

    // Mesh→grid decoder.
    let h = b.linear(v, cfg.latent, true, "dec.0");
    let h = b.ew1(EwKind::Silu, h, "dec.swish");
    let out = b.linear(h, cfg.out_feat, true, "dec.1");
    if with_loss {
        b.loss(out, "wmse_loss");
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inference_op_count_near_paper() {
        // Paper Table 2: GRC inference has 35 ops.
        let g = inference(&GraphCastConfig::default());
        let n = g.n_compute_ops();
        assert!((30..=48).contains(&n), "GRC inference ops = {n}");
        assert!(g.validate().is_empty());
    }

    #[test]
    fn training_op_count_near_paper() {
        // Paper Table 2: GRC training has 101 ops.
        let g = training(&GraphCastConfig::default());
        let n = g.n_compute_ops();
        assert!((85..=135).contains(&n), "GRC training ops = {n}");
    }

    #[test]
    fn wide_latent() {
        let g = inference(&GraphCastConfig::default());
        let enc = g.nodes().iter().find(|n| n.name == "enc.0").unwrap();
        assert_eq!(enc.out.shape.trailing(), 512);
    }
}
