//! Llama 3 8B (Grattafiori et al., 2024) — "Language modeling" (paper
//! Table 1). Three use-cases, as in the paper's §3:
//!
//! * **training** — forward + backward over a token batch;
//! * **context ("ctx")** — the prefill step: full-sequence forward pass;
//! * **decode ("tok")** — autoregressive generation: one token per step,
//!   GEMMs degenerate to skinny GEMV-like shapes (m = 1) whose traffic is
//!   dominated by weights, which is why Table 2 shows ~0% traffic
//!   reduction for LL-TOK under both fusion schemes.
//!
//! The captured graph covers a representative 2-layer window of the
//! 32-layer model plus the LM head — 27 operators, matching Table 2's
//! LL-CTX/LL-TOK row (application totals are per-window; full-model time
//! is the window repeated 16x, which leaves relative speedups unchanged).

use crate::graph::{training_graph, AutodiffOptions, EwKind, Graph, GraphBuilder, GraphKind, NodeId};

/// Model configuration (Llama-3-8B dimensions).
#[derive(Debug, Clone)]
pub struct LlamaConfig {
    pub seq: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub ffn_hidden: usize,
    pub n_layers: usize,
    pub vocab: usize,
    /// Decode mode: m=1 GEMMs against a KV cache of length `seq`.
    pub decode: bool,
}

impl LlamaConfig {
    /// Context (prefill) phase.
    pub fn context(seq: usize) -> Self {
        LlamaConfig {
            seq,
            d_model: 4096,
            n_heads: 32,
            ffn_hidden: 14336,
            n_layers: 2,
            vocab: 32000, // head truncated for simulation tractability
            decode: false,
        }
    }

    /// Decode (token-generation) phase with a KV cache of `kv_len`.
    pub fn decode(kv_len: usize) -> Self {
        LlamaConfig { decode: true, ..Self::context(kv_len) }
    }
}

/// Forward (inference) graph for ctx or tok phase.
pub fn inference(cfg: &LlamaConfig) -> Graph {
    build(cfg, false)
}

/// Training graph (always full-sequence).
pub fn training(cfg: &LlamaConfig) -> Graph {
    assert!(!cfg.decode, "training uses the full-sequence graph");
    let fwd = build(cfg, true);
    training_graph(&fwd, AutodiffOptions::default())
}

fn block(b: &mut GraphBuilder, x: NodeId, cfg: &LlamaConfig, li: usize) -> NodeId {
    let m = if cfg.decode { 1 } else { cfg.seq };
    let kv = cfg.seq; // decode attends over the KV cache
    let dh = cfg.d_model / cfg.n_heads;
    let nm = |s: &str| format!("layer{li}.{s}");

    // Attention.
    let ln1 = b.layernorm(x, &nm("rmsnorm1"));
    let qkv = b.linear(ln1, 3 * cfg.d_model, false, &nm("qkv"));
    let rope = b.ew1(EwKind::Rope, qkv, &nm("rope"));
    let scores = b.matmul(rope, rope, cfg.n_heads, m, kv, dh, &nm("scores"));
    let probs = b.softmax(scores, &nm("softmax"));
    let ctx = b.matmul(probs, rope, cfg.n_heads, m, dh, kv, &nm("ctx"));
    let attn = b.linear(ctx, cfg.d_model, false, &nm("out_proj"));
    let res1 = b.ew2(EwKind::Add, x, attn, &nm("res1"));

    // FFN (SwiGLU modeled at aten granularity: up GEMM, silu, down GEMM).
    let ln2 = b.layernorm(res1, &nm("rmsnorm2"));
    let up = b.linear(ln2, cfg.ffn_hidden, false, &nm("ffn_up"));
    let act = b.ew1(EwKind::Silu, up, &nm("ffn_silu"));
    let down = b.linear(act, cfg.d_model, false, &nm("ffn_down"));
    b.ew2(EwKind::Add, res1, down, &nm("res2"))
}

fn build(cfg: &LlamaConfig, with_loss: bool) -> Graph {
    let name = if cfg.decode { "llama-tok" } else if with_loss { "llama" } else { "llama-ctx" };
    let mut b = GraphBuilder::new(name, GraphKind::Inference);
    let m = if cfg.decode { 1 } else { cfg.seq };
    let mut x = b.input(&[m, cfg.d_model], "hidden_in");
    for li in 0..cfg.n_layers {
        x = block(&mut b, x, cfg, li);
    }
    let y = b.linear(x, cfg.vocab, false, "lm_head");
    if with_loss {
        b.loss(y, "xent_loss");
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctx_op_count_matches_paper() {
        // Paper Table 2: LL-CTX has 27 ops.
        let g = inference(&LlamaConfig::context(2048));
        let n = g.n_compute_ops();
        assert!((25..=29).contains(&n), "LL-CTX ops = {n}");
        assert!(g.validate().is_empty());
    }

    #[test]
    fn training_op_count_near_paper() {
        // Paper Table 2: LLAMA training has 88 ops.
        let g = training(&LlamaConfig::context(2048));
        let n = g.n_compute_ops();
        assert!((70..=105).contains(&n), "LLAMA training ops = {n}");
    }

    #[test]
    fn decode_gemms_are_skinny() -> crate::Result<()> {
        use crate::graph::OpKind;
        let g = inference(&LlamaConfig::decode(2048));
        let qkv = g.nodes().iter().find(|n| n.name == "layer0.qkv").unwrap();
        match qkv.op {
            OpKind::Matmul { m, .. } => assert_eq!(m, 1),
            ref o => anyhow::bail!("layer0.qkv lowered to {o:?}, not a matmul"),
        }
        Ok(())
    }

    #[test]
    fn ctx_gemms_are_fat() -> crate::Result<()> {
        use crate::graph::OpKind;
        let g = inference(&LlamaConfig::context(2048));
        let qkv = g.nodes().iter().find(|n| n.name == "layer0.qkv").unwrap();
        match qkv.op {
            OpKind::Matmul { m, .. } => assert_eq!(m, 2048),
            ref o => anyhow::bail!("layer0.qkv lowered to {o:?}, not a matmul"),
        }
        Ok(())
    }
}
