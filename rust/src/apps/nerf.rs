//! NeRF (Mildenhall et al., 2021) — "View synthesis" (paper Table 1).
//!
//! The original NeRF MLP: 8 fully-connected ReLU layers of width 256
//! (paper footnote 3: "the original NERF configuration which uses hidden
//! dim = 256"), a skip connection that re-concatenates the positional
//! encoding at layer 5, then density and view-dependent color heads.
//! Every operator is spatially fusable — the paper reports 100% Kitsune
//! coverage and a 98.6% traffic reduction; the concats ride the SIMT
//! pipes while the GEMMs use the TensorCores (§6.3).

use crate::graph::{training_graph, AutodiffOptions, EwKind, Graph, GraphBuilder, GraphKind};

/// Model configuration (original NeRF).
#[derive(Debug, Clone)]
pub struct NerfConfig {
    /// Ray-samples per batch (rays × samples/ray).
    pub batch: usize,
    /// Positional-encoding width of the input (L=10 -> 60).
    pub pos_enc: usize,
    /// View-direction encoding width (L=4 -> 24).
    pub dir_enc: usize,
    pub hidden: usize,
    pub depth: usize,
    /// Layer index where the skip concat re-injects the input.
    pub skip_at: usize,
}

impl Default for NerfConfig {
    fn default() -> Self {
        NerfConfig { batch: 65536, pos_enc: 60, dir_enc: 24, hidden: 256, depth: 8, skip_at: 5 }
    }
}

/// Forward (inference) graph.
pub fn inference(cfg: &NerfConfig) -> Graph {
    build(cfg, false)
}

/// Training graph: forward + photometric MSE + backward + optimizer.
pub fn training(cfg: &NerfConfig) -> Graph {
    let fwd = build(cfg, true);
    training_graph(&fwd, AutodiffOptions::default())
}

fn build(cfg: &NerfConfig, with_loss: bool) -> Graph {
    let mut b = GraphBuilder::new("nerf", GraphKind::Inference);
    let pos = b.input(&[cfg.batch, cfg.pos_enc], "pos_enc");
    let dir = b.input(&[cfg.batch, cfg.dir_enc], "dir_enc");
    let mut x = pos;
    for i in 0..cfg.depth {
        if i == cfg.skip_at {
            x = b.concat(&[x, pos], "skip_cat");
        }
        x = b.linear(x, cfg.hidden, true, &format!("trunk.{i}"));
        x = b.relu(x, &format!("trunk.{i}.relu"));
    }
    // Density head (no activation — raw sigma) and feature branch.
    let _sigma = b.linear(x, 1, true, "sigma_head");
    let feat = b.linear(x, cfg.hidden, true, "feat");
    let vcat = b.concat(&[feat, dir], "view_cat");
    let h = b.linear(vcat, cfg.hidden / 2, true, "rgb.0");
    let h = b.relu(h, "rgb.0.relu");
    let rgb = b.linear(h, 3, true, "rgb.1");
    let out = b.ew1(EwKind::Sigmoid, rgb, "rgb.sigmoid");
    if with_loss {
        b.loss(out, "mse_loss");
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inference_op_count_near_paper() {
        // Paper Table 2: NERF inference has 24 ops.
        let g = inference(&NerfConfig::default());
        let n = g.n_compute_ops();
        assert!((22..=28).contains(&n), "NeRF inference ops = {n}");
        assert!(g.validate().is_empty());
    }

    #[test]
    fn training_op_count_near_paper() {
        // Paper Table 2: NERF training has 69 ops.
        let g = training(&NerfConfig::default());
        let n = g.n_compute_ops();
        assert!((55..=100).contains(&n), "NeRF training ops = {n}");
    }

    #[test]
    fn everything_fusable() {
        // 100% Kitsune coverage: no excluded op kinds in the forward pass.
        let g = inference(&NerfConfig::default());
        assert!(g.compute_nodes().all(|n| !n.op.excluded_from_subgraphs()));
    }

    #[test]
    fn hidden_dim_is_256() {
        let g = inference(&NerfConfig::default());
        let trunk0 = g.nodes().iter().find(|n| n.name == "trunk.0").unwrap();
        assert_eq!(trunk0.out.shape.trailing(), 256);
    }
}
