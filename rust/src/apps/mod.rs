//! The paper's five challenge applications (Table 1) as graph builders:
//! DLRM, MeshGraphNets, NeRF, GraphCast, Llama-3-8B (ctx / tok / train).
//!
//! Shapes follow each model's published configuration, scaled where needed
//! for simulation tractability (documented per module); operator counts
//! land in the bands of the paper's Table 2.

pub mod dlrm;
pub mod graphcast;
pub mod llama;
pub mod mgn;
pub mod nerf;

use crate::graph::Graph;

/// Llama prefill sequence length used across the evaluation.
pub const LLAMA_SEQ: usize = 2048;

/// The inference evaluation suite — the six bars of Figs 3/10/11/13.
pub fn inference_suite() -> Vec<(String, Graph)> {
    vec![
        ("DLRM".into(), dlrm::inference(&dlrm::DlrmConfig::default())),
        ("GRC".into(), graphcast::inference(&graphcast::GraphCastConfig::default())),
        ("MGN".into(), mgn::inference(&mgn::MgnConfig::default())),
        ("NERF".into(), nerf::inference(&nerf::NerfConfig::default())),
        ("LL-CTX".into(), llama::inference(&llama::LlamaConfig::context(LLAMA_SEQ))),
        ("LL-TOK".into(), llama::inference(&llama::LlamaConfig::decode(LLAMA_SEQ))),
    ]
}

/// The training evaluation suite — the five bars of Figs 12/14.
pub fn training_suite() -> Vec<(String, Graph)> {
    vec![
        ("DLRM".into(), dlrm::training(&dlrm::DlrmConfig::default())),
        ("GRC".into(), graphcast::training(&graphcast::GraphCastConfig::default())),
        ("MGN".into(), mgn::training(&mgn::MgnConfig::default())),
        ("NERF".into(), nerf::training(&nerf::NerfConfig::default())),
        ("LLAMA".into(), llama::training(&llama::LlamaConfig::context(LLAMA_SEQ))),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suites_are_complete_and_valid() {
        let inf = inference_suite();
        assert_eq!(inf.len(), 6);
        for (name, g) in &inf {
            assert!(g.validate().is_empty(), "{name}: {:?}", g.validate());
            assert!(g.n_compute_ops() > 10, "{name}");
        }
        let tr = training_suite();
        assert_eq!(tr.len(), 5);
        for (name, g) in &tr {
            assert!(g.validate().is_empty(), "{name}");
            assert!(g.backward_start.is_some(), "{name} has no backward pass");
        }
    }
}
