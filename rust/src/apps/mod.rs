//! The paper's five challenge applications (Table 1) as graph builders:
//! DLRM, MeshGraphNets, NeRF, GraphCast, Llama-3-8B (ctx / tok / train).
//!
//! Shapes follow each model's published configuration, scaled where needed
//! for simulation tractability (documented per module); operator counts
//! land in the bands of the paper's Table 2.

pub mod dlrm;
pub mod graphcast;
pub mod llama;
pub mod mgn;
pub mod nerf;

use crate::graph::Graph;

/// Llama prefill sequence length used across the evaluation.
pub const LLAMA_SEQ: usize = 2048;

/// The inference evaluation suite — the six bars of Figs 3/10/11/13.
pub fn inference_suite() -> Vec<(String, Graph)> {
    vec![
        ("DLRM".into(), dlrm::inference(&dlrm::DlrmConfig::default())),
        ("GRC".into(), graphcast::inference(&graphcast::GraphCastConfig::default())),
        ("MGN".into(), mgn::inference(&mgn::MgnConfig::default())),
        ("NERF".into(), nerf::inference(&nerf::NerfConfig::default())),
        ("LL-CTX".into(), llama::inference(&llama::LlamaConfig::context(LLAMA_SEQ))),
        ("LL-TOK".into(), llama::inference(&llama::LlamaConfig::decode(LLAMA_SEQ))),
    ]
}

/// The training evaluation suite — the five bars of Figs 12/14.
pub fn training_suite() -> Vec<(String, Graph)> {
    vec![
        ("DLRM".into(), dlrm::training(&dlrm::DlrmConfig::default())),
        ("GRC".into(), graphcast::training(&graphcast::GraphCastConfig::default())),
        ("MGN".into(), mgn::training(&mgn::MgnConfig::default())),
        ("NERF".into(), nerf::training(&nerf::NerfConfig::default())),
        ("LLAMA".into(), llama::training(&llama::LlamaConfig::context(LLAMA_SEQ))),
    ]
}

/// Case-insensitive app lookup in one suite: exact name first, then
/// substring. Returns the owned `(name, graph)` pair.
pub fn find_app(name: &str, training: bool) -> Option<(String, Graph)> {
    let mut suite = if training { training_suite() } else { inference_suite() };
    let lower = name.to_lowercase();
    let idx = suite
        .iter()
        .position(|(n, _)| n.eq_ignore_ascii_case(name))
        .or_else(|| suite.iter().position(|(n, _)| n.to_lowercase().contains(&lower)))?;
    Some(suite.swap_remove(idx))
}

/// Every valid app name across both suites, training names annotated —
/// the vocabulary quoted by "unknown app" errors.
pub fn app_names() -> Vec<String> {
    let mut names: Vec<String> = inference_suite().into_iter().map(|(n, _)| n).collect();
    names.extend(training_suite().into_iter().map(|(n, _)| format!("{n} (training)")));
    names
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn find_app_searches_exact_then_substring() {
        let (n, g) = find_app("nerf", false).unwrap();
        assert_eq!(n, "NERF");
        assert!(g.backward_start.is_none());
        let (n, g) = find_app("MGN", true).unwrap();
        assert_eq!(n, "MGN");
        assert!(g.backward_start.is_some());
        // Substring: "ctx" hits LL-CTX; training-only LLAMA resolves there.
        assert_eq!(find_app("ctx", false).unwrap().0, "LL-CTX");
        assert_eq!(find_app("LLAMA", true).unwrap().0, "LLAMA");
        assert!(find_app("no-such-app", false).is_none());
        // The error vocabulary covers both suites.
        let names = app_names();
        assert!(names.iter().any(|n| n == "LL-TOK"));
        assert!(names.iter().any(|n| n == "LLAMA (training)"));
    }

    #[test]
    fn suites_are_complete_and_valid() {
        let inf = inference_suite();
        assert_eq!(inf.len(), 6);
        for (name, g) in &inf {
            assert!(g.validate().is_empty(), "{name}: {:?}", g.validate());
            assert!(g.n_compute_ops() > 10, "{name}");
        }
        let tr = training_suite();
        assert_eq!(tr.len(), 5);
        for (name, g) in &tr {
            assert!(g.validate().is_empty(), "{name}");
            assert!(g.backward_start.is_some(), "{name} has no backward pass");
        }
    }
}
