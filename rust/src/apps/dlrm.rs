//! DLRM (Naumov et al., 2019) — "Predicting ad clicks" (paper Table 1).
//!
//! Bottom MLP over dense features, embedding-bag gathers for sparse
//! features (excluded from sf-nodes per §5.1 — they index across all
//! data), pairwise feature interaction (batched dot products, the op whose
//! backward dominates DLRM training per §6.4), and a top MLP to the CTR
//! logit.

use crate::graph::{EwKind, Graph, GraphBuilder, GraphKind};
use crate::graph::{training_graph, AutodiffOptions};

/// Model configuration (MLPerf-style small DLRM).
#[derive(Debug, Clone)]
pub struct DlrmConfig {
    pub batch: usize,
    pub dense_features: usize,
    pub embedding_dim: usize,
    pub n_embedding_bags: usize,
    pub table_rows: usize,
    pub bottom_mlp: Vec<usize>,
    pub top_mlp: Vec<usize>,
}

impl Default for DlrmConfig {
    fn default() -> Self {
        DlrmConfig {
            batch: 2048,
            dense_features: 13,
            embedding_dim: 128,
            n_embedding_bags: 2, // grouped embedding-bag kernels
            table_rows: 1_000_000,
            bottom_mlp: vec![512, 256, 128],
            top_mlp: vec![1024, 1024, 512, 1],
        }
    }
}

/// Forward (inference) graph.
pub fn inference(cfg: &DlrmConfig) -> Graph {
    build(cfg, false)
}

/// Training graph: forward + BCE loss + backward + optimizer.
pub fn training(cfg: &DlrmConfig) -> Graph {
    let fwd = build(cfg, true);
    training_graph(&fwd, AutodiffOptions::default())
}

/// The *dense* DLRM training graph: bottom MLP → top MLP → sigmoid →
/// loss, with the embedding-bag gathers and the pairwise interaction
/// left out. This is the subset that streams end-to-end through
/// `kitsune::train` (the gathers are §5.1-excluded and keep the full
/// model on `Session::simulate()` — the typed fallback names them).
pub fn dense_training(cfg: &DlrmConfig) -> Graph {
    let mut b = GraphBuilder::new("dlrm-dense", GraphKind::Inference);
    let dense = b.input(&[cfg.batch, cfg.dense_features], "dense");
    let mut x = dense;
    for (i, &w) in cfg.bottom_mlp.iter().enumerate() {
        x = b.linear(x, w, true, &format!("bot.{i}"));
        x = b.relu(x, &format!("bot.{i}.relu"));
    }
    let last = cfg.top_mlp.len() - 1;
    for (i, &w) in cfg.top_mlp.iter().enumerate() {
        x = b.linear(x, w, true, &format!("top.{i}"));
        if i < last {
            x = b.relu(x, &format!("top.{i}.relu"));
        }
    }
    let logit = b.ew1(EwKind::Sigmoid, x, "sigmoid");
    b.loss(logit, "bce_loss");
    training_graph(&b.finish(), AutodiffOptions::default())
}

fn build(cfg: &DlrmConfig, with_loss: bool) -> Graph {
    let mut b = GraphBuilder::new("dlrm", GraphKind::Inference);
    // Bottom MLP over dense features.
    let dense = b.input(&[cfg.batch, cfg.dense_features], "dense");
    let mut x = dense;
    for (i, &w) in cfg.bottom_mlp.iter().enumerate() {
        x = b.linear(x, w, true, &format!("bot.{i}"));
        x = b.relu(x, &format!("bot.{i}.relu"));
    }
    // Sparse features: grouped embedding-bag gathers (excluded ops).
    let mut feats = vec![x];
    for t in 0..cfg.n_embedding_bags {
        let idx = b.input(&[cfg.batch], &format!("sparse.{t}"));
        let e = b.gather(idx, cfg.table_rows, cfg.embedding_dim, &format!("emb.{t}"));
        feats.push(e);
    }
    // Pairwise feature interaction (Z = X·Xᵀ lower triangle).
    let cat = b.concat(&feats, "feat_cat");
    let n_feat = 1 + cfg.n_embedding_bags;
    let inter = b.interaction(cat, n_feat, cfg.embedding_dim, "interaction");
    // Top MLP over [bottom_out, interactions].
    let top_in = b.concat(&[x, inter], "top_cat");
    let mut y = top_in;
    let last = cfg.top_mlp.len() - 1;
    for (i, &w) in cfg.top_mlp.iter().enumerate() {
        y = b.linear(y, w, true, &format!("top.{i}"));
        if i < last {
            y = b.relu(y, &format!("top.{i}.relu"));
        }
    }
    let logit = b.ew1(EwKind::Sigmoid, y, "sigmoid");
    if with_loss {
        b.loss(logit, "bce_loss");
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inference_op_count_near_paper() {
        // Paper Table 2: DLRM inference has 21 ops.
        let g = inference(&DlrmConfig::default());
        let n = g.n_compute_ops();
        assert!((18..=26).contains(&n), "DLRM inference ops = {n}");
        assert!(g.validate().is_empty());
    }

    #[test]
    fn training_op_count_near_paper() {
        // Paper Table 2: DLRM training has 59 ops.
        let g = training(&DlrmConfig::default());
        let n = g.n_compute_ops();
        assert!((45..=75).contains(&n), "DLRM training ops = {n}");
        assert!(g.validate().is_empty());
    }

    #[test]
    fn has_excluded_gathers() {
        let g = inference(&DlrmConfig::default());
        assert!(g.compute_nodes().any(|n| n.op.excluded_from_subgraphs()));
    }

    #[test]
    fn dense_training_has_no_excluded_ops() {
        let g = dense_training(&DlrmConfig::default());
        assert!(g.validate().is_empty(), "{:?}", g.validate());
        assert!(g.backward_start.is_some());
        assert!(g.compute_nodes().all(|n| !n.op.excluded_from_subgraphs()));
    }
}
