//! # Kitsune — dataflow execution on GPUs, reproduced
//!
//! Full reproduction of *"Kitsune: Enabling Dataflow Execution on GPUs"*
//! (Davies, Crago, Sankaralingam, Keckler — NVIDIA, 2025) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The paper's pieces map onto this crate as follows:
//!
//! | Paper | Module |
//! |---|---|
//! | §4.1 ring-queue primitive (L2-resident, atomics) | [`queue`] |
//! | §4.2 dual-arbiter grid scheduler | [`sim::scheduler`] |
//! | §5.1 subgraph selection (pattern matching) | [`compiler::patterns`], [`compiler::subgraph`] |
//! | §5.2 pipeline design (Algorithm 1) | [`compiler::pipeline`] |
//! | §5.3 load balancing ILP (Algorithm 2) | [`compiler::load_balance`], [`ilp`] |
//! | §6 NVAS-based evaluation | [`sim`], [`exec`] |
//! | 5 applications (DLRM, MGN, NeRF, GraphCast, Llama-3-8B) | [`apps`] |
//! | PyTorch-Dynamo graph capture | [`graph`] (IR + reverse-mode autodiff) |
//! | CUDA spatial-pipeline runtime (Fig 6) | [`coordinator`] (real threads + ring queues) |
//! | Fig 6 host API (`cudaPipelineCreate` → `AddKernel` → launch) | [`session`] (builder → persistent pipeline → `submit`) |
//! | Training on dataflow (§6.4, Figs 12/14: multicast + skip links) | [`train`] (DAG pipeline, gradient taps, optimizer, `Trainer`) |
//! | §4 "keep every resource busy at once" on the host runtime | [`sched`] (one work-stealing pool under GEMM panels, stage pumps, DAG training) |
//! | Many independent requests through one persistent pipeline | [`serve`] (continuous batching, EDF deadlines, multi-model residency, SLO stats) |
//! | Failure as a first-class dataflow value | [`fault`] (typed `StageFailure`, poison tiles, health machine, supervised restart, deterministic injection) |
//! | §6 traffic/utilization measurement (Figs 9/13) | [`telemetry`] (per-stage metrics, edge stalls, traffic accounting, `KITSUNE_TRACE` span export) |
//!
//! [`session`] is the **single public entry point** for running anything:
//! `Session::builder().app("nerf").build()?` compiles once, lowers the
//! compiled plan onto the coordinator, and stands up persistent stage
//! worker pools; the same object exposes `simulate()` (the §6 simulator
//! evaluation) and `submit()/run()` (real streaming execution with
//! concurrent batch submission). The CLI, examples and benches all go
//! through it — hand-stitching `compile()` + `SpatialPipeline::builder()`
//! + `run_streaming()` is the deprecated path.
//!
//! The [`runtime`] executes artifact entries through a pluggable
//! [`runtime::Backend`]: the pure-Rust interpreter (default — a fresh
//! offline checkout builds, tests and serves with no XLA and no Python) or
//! PJRT under the off-by-default `pjrt` cargo feature. Python (JAX +
//! Pallas) appears only at build time: `python/compile/aot.py` lowers the
//! L2 model and L1 kernels to HLO *text* under `artifacts/` for the PJRT
//! path. Nothing on the request path imports Python.

pub mod graph;
pub mod apps;
pub mod sim;
pub mod queue;
pub mod perfmodel;
pub mod ilp;
pub mod compiler;
pub mod exec;
pub mod coordinator;
pub mod sched;
pub mod fault;
pub mod runtime;
pub mod session;
pub mod serve;
pub mod train;
pub mod telemetry;
pub mod report;
pub mod bench;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
