//! `relite` — a tiny regex engine for the §5.1 pattern library.
//!
//! The pattern library needs only a small regex subset over one-letter
//! operator mnemonics: literals, character classes (`[ME]`), groups
//! (`(E+M)`), and the quantifiers `?`, `*`, `+`, `{m}`, `{m,}`, `{m,n}`.
//! A full regex crate is unavailable offline, so this module implements
//! exactly that subset with a greedy backtracking matcher whose semantics
//! (leftmost-first preference, non-overlapping `find_iter` scan) were
//! validated against a reference regex engine on randomized inputs for
//! every pattern in [`super::patterns::PatternLib`].
//!
//! Strings are the ASCII letter encodings produced by
//! [`super::patterns::encode`]; the matcher operates on bytes.

use std::fmt;

/// Unbounded repetition sentinel.
const MANY: u32 = u32::MAX;

/// One matchable element.
#[derive(Debug, Clone)]
enum Elem {
    /// Literal byte.
    Lit(u8),
    /// Character class `[...]` (no ranges / negation — not needed).
    Class(Vec<u8>),
    /// Parenthesized group.
    Group(Vec<Piece>),
}

/// An element plus its repetition bounds.
#[derive(Debug, Clone)]
struct Piece {
    elem: Elem,
    min: u32,
    max: u32,
}

/// Compiled pattern.
#[derive(Debug, Clone)]
pub struct Regex {
    pieces: Vec<Piece>,
    pattern: String,
}

/// A located match, mirroring `regex::Match`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Match {
    start: usize,
    end: usize,
}

impl Match {
    pub fn start(&self) -> usize {
        self.start
    }

    pub fn end(&self) -> usize {
        self.end
    }
}

/// Pattern-compilation error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError(String);

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "relite: {}", self.0)
    }
}

impl std::error::Error for ParseError {}

impl Regex {
    /// Compile a pattern from the supported subset.
    pub fn new(pattern: &str) -> Result<Regex, ParseError> {
        let bytes = pattern.as_bytes();
        let (pieces, rest) = parse_seq(bytes, 0, 0)?;
        if rest != bytes.len() {
            return Err(ParseError(format!("unbalanced ')' in `{pattern}`")));
        }
        Ok(Regex { pieces, pattern: pattern.to_string() })
    }

    /// The source pattern.
    pub fn as_str(&self) -> &str {
        &self.pattern
    }

    /// Leftmost match at or after the start of `text`.
    pub fn find(&self, text: &str) -> Option<Match> {
        let t = text.as_bytes();
        (0..=t.len()).find_map(|s| {
            match_seq(t, &self.pieces, s).map(|e| Match { start: s, end: e })
        })
    }

    /// Whether the pattern matches anywhere in `text`.
    pub fn is_match(&self, text: &str) -> bool {
        self.find(text).is_some()
    }

    /// Non-overlapping matches, left to right (the `regex` crate's
    /// `find_iter` scan: resume after each match's end, advancing by one
    /// past any empty match).
    pub fn find_iter(&self, text: &str) -> Vec<Match> {
        let t = text.as_bytes();
        let mut out = Vec::new();
        let mut pos = 0usize;
        while pos <= t.len() {
            match match_seq(t, &self.pieces, pos) {
                Some(end) => {
                    out.push(Match { start: pos, end });
                    pos = if end > pos { end } else { pos + 1 };
                }
                None => pos += 1,
            }
        }
        out
    }
}

/// Parse a concatenation until end-of-pattern or a closing `)`.
/// Returns the pieces and the index just past what was consumed
/// (including the `)` when `depth > 0`).
fn parse_seq(pat: &[u8], mut i: usize, depth: u32) -> Result<(Vec<Piece>, usize), ParseError> {
    let mut pieces = Vec::new();
    while i < pat.len() {
        let elem = match pat[i] {
            b')' => {
                if depth == 0 {
                    return Err(ParseError("unbalanced ')'".into()));
                }
                return Ok((pieces, i + 1));
            }
            b'(' => {
                // The recursive call consumes through the matching ')'
                // (or errors itself on premature end-of-pattern).
                let (inner, next) = parse_seq(pat, i + 1, depth + 1)?;
                i = next;
                Elem::Group(inner)
            }
            b'[' => {
                let close = pat[i..]
                    .iter()
                    .position(|&b| b == b']')
                    .ok_or_else(|| ParseError("missing ']'".into()))?
                    + i;
                let class: Vec<u8> = pat[i + 1..close].to_vec();
                if class.is_empty() {
                    return Err(ParseError("empty class '[]'".into()));
                }
                i = close + 1;
                Elem::Class(class)
            }
            b'?' | b'*' | b'+' | b'{' => {
                return Err(ParseError("dangling quantifier".into()));
            }
            c => {
                i += 1;
                Elem::Lit(c)
            }
        };
        let (min, max, next) = parse_quantifier(pat, i)?;
        i = next;
        pieces.push(Piece { elem, min, max });
    }
    if depth > 0 {
        return Err(ParseError("unbalanced '('".into()));
    }
    Ok((pieces, i))
}

/// Parse an optional quantifier at `i`; returns `(min, max, next_index)`.
fn parse_quantifier(pat: &[u8], i: usize) -> Result<(u32, u32, usize), ParseError> {
    match pat.get(i).copied() {
        Some(b'?') => Ok((0, 1, i + 1)),
        Some(b'*') => Ok((0, MANY, i + 1)),
        Some(b'+') => Ok((1, MANY, i + 1)),
        Some(b'{') => {
            let close = pat[i..]
                .iter()
                .position(|&b| b == b'}')
                .ok_or_else(|| ParseError("missing '}'".into()))?
                + i;
            let body = std::str::from_utf8(&pat[i + 1..close])
                .map_err(|_| ParseError("non-utf8 bound".into()))?;
            let parse_n = |s: &str| {
                s.parse::<u32>().map_err(|_| ParseError(format!("bad repetition bound `{body}`")))
            };
            let (min, max) = match body.split_once(',') {
                None => {
                    let n = parse_n(body)?;
                    (n, n)
                }
                Some((lo, "")) => (parse_n(lo)?, MANY),
                Some((lo, hi)) => (parse_n(lo)?, parse_n(hi)?),
            };
            if max < min {
                return Err(ParseError(format!("inverted bounds `{{{body}}}`")));
            }
            Ok((min, max, close + 1))
        }
        _ => Ok((1, 1, i)),
    }
}

/// Match the full piece sequence at `pos`; returns the end of the first
/// (preference-order) complete match.
fn match_seq(text: &[u8], pieces: &[Piece], pos: usize) -> Option<usize> {
    let Some((piece, rest)) = pieces.split_first() else {
        return Some(pos);
    };
    match_reps(text, piece, rest, 0, pos)
}

/// Greedy repetition: prefer one more repetition of `piece` before moving
/// on to `rest` (Perl/leftmost-first preference order).
fn match_reps(text: &[u8], piece: &Piece, rest: &[Piece], done: u32, pos: usize) -> Option<usize> {
    if done < piece.max {
        for end in elem_ends(text, &piece.elem, pos) {
            // Zero-width repetitions cannot make progress; skip them so
            // unbounded quantifiers always terminate.
            if end > pos {
                if let Some(m) = match_reps(text, piece, rest, done + 1, end) {
                    return Some(m);
                }
            }
        }
    }
    if done >= piece.min {
        return match_seq(text, rest, pos);
    }
    None
}

/// All end positions of one `elem` occurrence starting at `pos`, in
/// preference order (greedy: longer first for groups, by construction).
fn elem_ends(text: &[u8], elem: &Elem, pos: usize) -> Vec<usize> {
    match elem {
        Elem::Lit(c) => {
            if text.get(pos) == Some(c) {
                vec![pos + 1]
            } else {
                Vec::new()
            }
        }
        Elem::Class(set) => {
            if pos < text.len() && set.contains(&text[pos]) {
                vec![pos + 1]
            } else {
                Vec::new()
            }
        }
        Elem::Group(seq) => {
            let mut out = Vec::new();
            collect_seq_ends(text, seq, pos, &mut out);
            out
        }
    }
}

/// Collect every end position of `pieces` matched from `pos`, preference
/// order, first occurrence kept on duplicates.
fn collect_seq_ends(text: &[u8], pieces: &[Piece], pos: usize, out: &mut Vec<usize>) {
    let Some((piece, rest)) = pieces.split_first() else {
        if !out.contains(&pos) {
            out.push(pos);
        }
        return;
    };
    collect_rep_ends(text, piece, rest, 0, pos, out);
}

fn collect_rep_ends(
    text: &[u8],
    piece: &Piece,
    rest: &[Piece],
    done: u32,
    pos: usize,
    out: &mut Vec<usize>,
) {
    if done < piece.max {
        for end in elem_ends(text, &piece.elem, pos) {
            if end > pos {
                collect_rep_ends(text, piece, rest, done + 1, end, out);
            }
        }
    }
    if done >= piece.min {
        collect_seq_ends(text, rest, pos, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spans(re: &str, text: &str) -> Vec<(usize, usize)> {
        Regex::new(re).unwrap().find_iter(text).iter().map(|m| (m.start(), m.end())).collect()
    }

    #[test]
    fn literals_and_classes() {
        assert_eq!(spans("ME", "XMEXME"), vec![(1, 3), (4, 6)]);
        assert_eq!(spans("[ME]+", "MEXEM"), vec![(0, 2), (3, 5)]);
        assert!(Regex::new("M").unwrap().is_match("XMX"));
        assert!(!Regex::new("M").unwrap().is_match("XEX"));
    }

    #[test]
    fn quantifiers() {
        assert_eq!(spans("ME?", "MME"), vec![(0, 1), (1, 3)]);
        assert_eq!(spans("ME*", "MEEEX"), vec![(0, 4)]);
        assert_eq!(spans("E{2,}", "EXEEXEEEE"), vec![(2, 4), (5, 9)]);
        assert_eq!(spans("E{2}", "EEEE"), vec![(0, 2), (2, 4)]);
        assert_eq!(spans("E{1,2}", "EEE"), vec![(0, 2), (2, 3)]);
    }

    #[test]
    fn groups_backtrack() {
        // `(E+M)+` must span alternations and leave the tail to `E*`.
        assert_eq!(spans("M(E+M)+E*", "MEMEEMEE"), vec![(0, 8)]);
        // Backtracking: the greedy group gives one rep back for the tail.
        assert_eq!(spans("(EE)+E", "EEE"), vec![(0, 3)]);
    }

    #[test]
    fn leftmost_first_preference() {
        // Greedy first piece wins even when a longer overall match exists
        // with a lazier split — matching the `regex` crate's semantics.
        assert_eq!(spans("(EE)?(EEE)?", "EEEE")[0], (0, 2));
    }

    #[test]
    fn paper_patterns_compile_and_match() {
        // The exact library patterns (kept in sync with patterns.rs).
        for p in [
            r"M+E*M?E*MS[ME]+",
            r"[LC]?M(E+M)+E*O?",
            r"[LC]?ME+R?O?",
            r"E+M+R?M*R?",
            r"[ME]+R+[EU]*",
            r"[LS][ME]+",
            r"E{2,}[RUO]*",
            r"[CE]*I[ME]*",
            r"MM+",
        ] {
            Regex::new(p).unwrap();
        }
        // Attention string: M M M E E M S M M — one end-to-end match.
        let att = Regex::new(r"M+E*M?E*MS[ME]+").unwrap();
        let ms = att.find_iter("MMMEEMSMM");
        assert_eq!(ms.len(), 1);
        assert_eq!((ms[0].start(), ms[0].end()), (0, 9));
        // MLP chain consumes the whole string.
        let mlp = Regex::new(r"[LC]?M(E+M)+E*O?").unwrap();
        let ms = mlp.find_iter("MEMEMEM");
        assert_eq!((ms[0].start(), ms[0].end()), (0, 7));
    }

    #[test]
    fn separators_block_spans() {
        let mlp = Regex::new(r"[LC]?M(E+M)+E*O?").unwrap();
        for m in mlp.find_iter("MEM|MEM") {
            assert!(!(m.start() < 3 && m.end() > 4), "match crossed separator");
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(Regex::new("(ME").is_err());
        assert!(Regex::new("ME)").is_err());
        assert!(Regex::new("[ME").is_err());
        assert!(Regex::new("*M").is_err());
        assert!(Regex::new("E{3,1}").is_err());
        assert!(Regex::new("E{x}").is_err());
    }
}
