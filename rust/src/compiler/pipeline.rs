//! §5.2 pipeline design (Algorithm 1): turn an sf-node into pipeline
//! stages connected by queue edges — splitting reductions into parallel
//! fan-in trees, fusing trivially-fusable epilogues, and inserting a queue
//! for every intermediate that stays on chip.

use super::subgraph::SfNode;
use crate::graph::{Graph, NodeId, OpKind, ResourceClass};
use std::collections::HashMap;

/// Fan-in width cap for split reductions (the queue many-to-one pattern).
pub const MAX_REDUCE_SPLIT: usize = 32;
/// Reductions narrower than this are not worth splitting.
pub const MIN_SPLIT_FACTOR: usize = 16;

/// One pipeline stage: one operator, or an operator plus epilogue-fused
/// elementwise followers.
#[derive(Debug, Clone)]
pub struct StageSpec {
    /// Member nodes in topo order; `nodes[0]` is the anchor.
    pub nodes: Vec<NodeId>,
    pub class: ResourceClass,
    /// >1 for a split reduction: the stage is a parallel fan-in tree of
    /// this width (Algorithm 1's `SplitReduction`), raising its
    /// parallelism cap from "a small number of CTAs" to `split`.
    pub parallel_split: usize,
}

/// A queue edge between stages, carrying the output of `producer_node`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QueueEdge {
    pub from_stage: usize,
    pub to_stage: usize,
    pub producer_node: NodeId,
}

/// Pipeline design output for one sf-node.
#[derive(Debug, Clone)]
pub struct PipelineSpec {
    pub sf_id: usize,
    pub pattern: String,
    pub stages: Vec<StageSpec>,
    pub edges: Vec<QueueEdge>,
}

impl PipelineSpec {
    pub fn n_nodes(&self) -> usize {
        self.stages.iter().map(|s| s.nodes.len()).sum()
    }
}

/// Algorithm 1: design the pipeline for one sf-node.
pub fn design_pipeline(g: &Graph, sf: &SfNode) -> PipelineSpec {
    let member: HashMap<NodeId, usize> =
        sf.nodes.iter().enumerate().map(|(i, &n)| (n, i)).collect();

    // 1. Assign each member node a provisional stage.
    //    Epilogue fusion: an elementwise op whose sole producer-in-sf is a
    //    GEMM stage with no other sf-consumer merges into that stage
    //    ("if the work done between two nodes is trivially fusable, fuse
    //    them using epilogue fusion").
    let mut stage_of: HashMap<NodeId, usize> = HashMap::new();
    let mut stages: Vec<StageSpec> = Vec::new();
    for &nid in &sf.nodes {
        let node = g.node(nid);
        let mut fused_into: Option<usize> = None;
        if matches!(node.op, OpKind::Elementwise(_)) {
            // Producers inside the sf-node.
            let sf_inputs: Vec<NodeId> = node
                .inputs
                .iter()
                .copied()
                .filter(|i| member.contains_key(i))
                .collect();
            if sf_inputs.len() == 1 {
                let p = sf_inputs[0];
                let p_stage = stage_of.get(&p).copied();
                if let Some(ps) = p_stage {
                    let anchor = g.node(stages[ps].nodes[0]);
                    let single_consumer = g
                        .consumers(p)
                        .iter()
                        .filter(|c| member.contains_key(c))
                        .count()
                        == 1;
                    if matches!(anchor.op, OpKind::Matmul { .. }) && single_consumer {
                        fused_into = Some(ps);
                    }
                }
            }
        }
        match fused_into {
            Some(ps) => {
                stages[ps].nodes.push(nid);
                stage_of.insert(nid, ps);
            }
            None => {
                // 2. SplitReduction: wide reductions become parallel
                //    fan-in stages (Fig 2(b) / Algorithm 1 lines 2-6).
                let split = match &node.op {
                    OpKind::Reduce { factor, .. } if *factor >= MIN_SPLIT_FACTOR => {
                        (*factor).min(MAX_REDUCE_SPLIT)
                    }
                    _ => 1,
                };
                let idx = stages.len();
                stages.push(StageSpec {
                    nodes: vec![nid],
                    class: node.resource_class(),
                    parallel_split: split,
                });
                stage_of.insert(nid, idx);
            }
        }
    }

    // 3. CreateQueue: one queue edge per intra-sf producer→consumer stage
    //    pair (multicast = several edges from one producer, Fig 2(c)).
    let mut edges: Vec<QueueEdge> = Vec::new();
    for &nid in &sf.nodes {
        let to_stage = stage_of[&nid];
        for &inp in &g.node(nid).inputs {
            if let Some(&from_stage) = stage_of.get(&inp) {
                if from_stage != to_stage {
                    let e = QueueEdge { from_stage, to_stage, producer_node: inp };
                    if !edges.contains(&e) {
                        edges.push(e);
                    }
                }
            }
        }
    }

    PipelineSpec { sf_id: sf.id, pattern: sf.pattern.clone(), stages, edges }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::patterns::PatternLib;
    use crate::compiler::subgraph::{select_subgraphs, SelectOptions};
    use crate::graph::{training_graph, AutodiffOptions, EwKind, GraphBuilder, GraphKind};

    fn designed(g: &Graph) -> Vec<PipelineSpec> {
        let sel = select_subgraphs(g, &PatternLib::standard(), &SelectOptions::default());
        sel.sf_nodes.iter().map(|sf| design_pipeline(g, sf)).collect()
    }

    #[test]
    fn mlp_epilogue_fuses_relu_into_gemm() {
        let mut b = GraphBuilder::new("mlp", GraphKind::Inference);
        let x = b.input(&[1024, 256], "x");
        b.mlp(x, &[1024, 256], EwKind::Relu, false, "ffn");
        let g = b.finish();
        let ps = designed(&g);
        assert_eq!(ps.len(), 1);
        let p = &ps[0];
        // linear+relu fuse -> 2 stages (gemm+epilogue, gemm), 1 queue edge.
        assert_eq!(p.stages.len(), 2, "{p:?}");
        assert_eq!(p.stages[0].nodes.len(), 2);
        assert_eq!(p.edges.len(), 1);
    }

    #[test]
    fn multicast_gets_two_edges() {
        // One ew output feeding two GEMMs (Fig 2(c)).
        let mut b = GraphBuilder::new("mc", GraphKind::Inference);
        let x = b.input(&[512, 512], "x");
        let e = b.relu(x, "act");
        let m1 = b.linear(e, 512, false, "g1");
        let _m2 = b.linear(e, 512, false, "g2");
        let _ = b.ew2(EwKind::Add, m1, _m2, "join");
        let g = b.finish();
        let ps = designed(&g);
        assert_eq!(ps.len(), 1, "{ps:?}");
        let p = &ps[0];
        let from_act: Vec<_> = p
            .edges
            .iter()
            .filter(|ed| ed.producer_node == e)
            .collect();
        assert_eq!(from_act.len(), 2, "{p:?}");
    }

    #[test]
    fn training_reductions_get_split() {
        let mut b = GraphBuilder::new("t", GraphKind::Inference);
        let x = b.input(&[4096, 512], "x");
        let h = b.linear(x, 512, true, "fc");
        let a = b.relu(h, "act");
        b.loss(a, "loss");
        let fwd = b.finish();
        let tg = training_graph(&fwd, AutodiffOptions { optimizer_updates: false });
        let ps = designed(&tg);
        let split_stages: Vec<_> = ps
            .iter()
            .flat_map(|p| &p.stages)
            .filter(|s| s.parallel_split > 1)
            .collect();
        assert!(
            !split_stages.is_empty(),
            "bias grad reduce should be split: {ps:#?}"
        );
        assert!(split_stages.iter().all(|s| s.parallel_split <= MAX_REDUCE_SPLIT));
    }

    #[test]
    fn edges_reference_valid_stages() {
        let mut b = GraphBuilder::new("mlp", GraphKind::Inference);
        let x = b.input(&[2048, 256], "x");
        b.mlp(x, &[1024, 1024, 256], EwKind::Gelu, true, "net");
        let g = b.finish();
        for p in designed(&g) {
            for e in &p.edges {
                assert!(e.from_stage < p.stages.len());
                assert!(e.to_stage < p.stages.len());
                assert!(e.from_stage < e.to_stage, "queues flow forward: {e:?}");
            }
        }
    }
}
