//! §5.3 load balancing (Algorithm 2): allocate CTAs to pipeline stages via
//! the max-min ILP, over-subscribing SMs with one SIMT-heavy and one
//! TensorCore-heavy CTA each.

use super::pipeline::{PipelineSpec, StageSpec};
use crate::graph::{Graph, ResourceClass};
use crate::ilp::{solve_maxmin, AllocVar, Allocation};
use crate::perfmodel;
use crate::sim::GpuConfig;
use anyhow::{anyhow, Result};

/// A load-balanced pipeline: the design plus its CTA allocation.
#[derive(Debug, Clone)]
pub struct BalancedPipeline {
    pub spec: PipelineSpec,
    /// CTAs per stage (the ILP's `a_i`).
    pub alloc: Vec<usize>,
    /// ILP objective: sf-node iterations/second before the DRAM/L2 caps.
    pub ilp_throughput: f64,
    /// Post-cap estimate (the `thrpt * Bytes < Peak` rows of Algorithm 2).
    pub est_throughput: f64,
}

/// Stage-level work summary used to form the ILP coefficients.
#[derive(Debug, Clone)]
pub struct StageWork {
    pub flops: f64,
    pub dram_bytes: f64,
    pub l2_bytes: f64,
    pub u: f64,
    pub class: ResourceClass,
    pub natural_ctas: usize,
}

/// Sum work across a stage's member nodes under the pipeline's I/O
/// placement (`io_of` maps node-local placement decisions; see lower.rs).
pub fn stage_work(
    g: &Graph,
    stage: &StageSpec,
    io_of: impl Fn(crate::graph::NodeId) -> perfmodel::IoPlacement,
) -> StageWork {
    let mut flops = 0.0;
    let mut dram = 0.0;
    let mut l2 = 0.0;
    for &nid in &stage.nodes {
        let node = g.node(nid);
        flops += node.flops();
        let (d, l) = perfmodel::traffic(node, g, &io_of(nid));
        dram += d;
        l2 += l;
    }
    let anchor = g.node(stage.nodes[0]);
    let natural = perfmodel::natural_ctas(anchor) * stage.parallel_split;
    StageWork {
        flops,
        dram_bytes: dram,
        l2_bytes: l2,
        u: perfmodel::pipe_utilization(anchor),
        class: stage.class,
        natural_ctas: natural.max(1),
    }
}

/// Algorithm 2. `works[i]` describes stage `i`'s per-sf-iteration work.
pub fn balance(
    spec: &PipelineSpec,
    works: &[StageWork],
    cfg: &GpuConfig,
) -> Result<BalancedPipeline> {
    assert_eq!(spec.stages.len(), works.len());
    // Per-CTA sustainable L2/DRAM bandwidth (a single CTA has bounded
    // memory-level parallelism; ~L2_bw / #SMs).
    let per_cta_bw = cfg.l2_bw / cfg.sm_count as f64;

    let vars: Vec<AllocVar> = works
        .iter()
        .map(|w| {
            let pipe = match w.class {
                ResourceClass::Tensor => cfg.tensor_flops_per_sm(),
                ResourceClass::Simt => cfg.simt_flops_per_sm(),
            };
            // One-CTA stage time: compute at `u` of its pipe share
            // (s_i = 1/u is already reflected: time uses compute only —
            // memory round trips are gone in spatial mode, enforced
            // globally by the bandwidth caps below).
            let t_compute = w.flops / (pipe * w.u).max(1.0);
            let t_mem = (w.dram_bytes + w.l2_bytes) / per_cta_bw;
            let t = t_compute.max(t_mem).max(1e-12);
            AllocVar {
                coeff: 1.0 / t,
                class: match w.class {
                    ResourceClass::Tensor => 0,
                    ResourceClass::Simt => 1,
                },
                cap: w.natural_ctas.min(cfg.sm_count),
            }
        })
        .collect();

    let budgets = [cfg.sm_count, cfg.sm_count];
    let Allocation { a, throughput } = solve_maxmin(&vars, &budgets)
        .ok_or_else(|| anyhow!("sf-node {} unbalanceable: too many stages", spec.sf_id))?;

    // Algorithm 2's bandwidth rows: thrpt * Bytes < Peak.
    let dram_bytes: f64 = works.iter().map(|w| w.dram_bytes).sum();
    let l2_bytes: f64 = works.iter().map(|w| w.l2_bytes).sum();
    let mut est = throughput;
    if dram_bytes > 0.0 {
        est = est.min(cfg.dram_bw / dram_bytes);
    }
    if l2_bytes > 0.0 {
        est = est.min(cfg.l2_bw / l2_bytes);
    }

    Ok(BalancedPipeline { spec: spec.clone(), alloc: a, ilp_throughput: throughput, est_throughput: est })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::patterns::PatternLib;
    use crate::compiler::pipeline::design_pipeline;
    use crate::compiler::subgraph::{select_subgraphs, SelectOptions};
    use crate::graph::{EwKind, GraphBuilder, GraphKind};
    use crate::perfmodel::IoPlacement;

    fn balanced_mlp() -> (BalancedPipeline, usize) {
        let mut b = GraphBuilder::new("mlp", GraphKind::Inference);
        let x = b.input(&[4096, 1024], "x");
        b.mlp(x, &[4096, 4096, 1024], EwKind::Gelu, false, "ffn");
        let g = b.finish();
        let sel = select_subgraphs(&g, &PatternLib::standard(), &SelectOptions::default());
        assert_eq!(sel.sf_nodes.len(), 1);
        let spec = design_pipeline(&g, &sel.sf_nodes[0]);
        let works: Vec<StageWork> = spec
            .stages
            .iter()
            .map(|s| stage_work(&g, s, |nid| IoPlacement::bsp(g.node(nid).inputs.len())))
            .collect();
        let cfg = GpuConfig::a100();
        let n_stages = spec.stages.len();
        (balance(&spec, &works, &cfg).unwrap(), n_stages)
    }

    #[test]
    fn allocation_covers_every_stage() {
        let (bp, n) = balanced_mlp();
        assert_eq!(bp.alloc.len(), n);
        assert!(bp.alloc.iter().all(|&a| a >= 1));
    }

    #[test]
    fn class_budgets_respected() {
        let (bp, _) = balanced_mlp();
        let cfg = GpuConfig::a100();
        let mut per_class = [0usize; 2];
        for (s, &a) in bp.spec.stages.iter().zip(&bp.alloc) {
            per_class[match s.class {
                ResourceClass::Tensor => 0,
                ResourceClass::Simt => 1,
            }] += a;
        }
        assert!(per_class[0] <= cfg.sm_count, "{per_class:?}");
        assert!(per_class[1] <= cfg.sm_count, "{per_class:?}");
    }

    #[test]
    fn throughput_positive_and_capped() {
        let (bp, _) = balanced_mlp();
        assert!(bp.ilp_throughput > 0.0);
        assert!(bp.est_throughput > 0.0);
        assert!(bp.est_throughput <= bp.ilp_throughput + 1e-9);
    }
}
