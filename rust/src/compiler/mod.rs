//! The Kitsune compiler (paper §5, Fig 7): subgraph selection → pipeline
//! design → load balance → lowering to spatial pipelines.
//!
//! The paper implements this as a PyTorch Dynamo backend; here the
//! captured graph is [`crate::graph::Graph`] and the output is a set of
//! simulator-/coordinator-ready [`crate::sim::PipelineDesc`]s plus a
//! topological execution plan.

pub mod patterns;
pub mod relite;
pub mod subgraph;
pub mod pipeline;
pub mod load_balance;
pub mod lower;

pub use load_balance::{balance, stage_work, BalancedPipeline, StageWork};
pub use lower::{compile, dataflow_io, lower_sf_node, CompiledApp, LoweredPipeline, PlanItem};
pub use patterns::{encode, letter, Pattern, PatternLib};
pub use pipeline::{design_pipeline, PipelineSpec, QueueEdge, StageSpec};
pub use subgraph::{select_subgraphs, SelectOptions, Selection, SfNode};
