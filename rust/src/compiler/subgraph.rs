//! §5.1 subgraph selection: pick sf-nodes (spatially-fused groups) from
//! the captured graph by pattern matching over the topological order,
//! subject to the paper's constraints — excluded node classes and
//! contiguity in the sense of Tarnawski et al. [47]: "there must be no
//! edge which exits the subgraph with a downstream edge that reenters it".

use super::patterns::{encode, PatternLib};
use crate::graph::{Graph, NodeId};
use std::collections::HashSet;

/// A selected spatially-fused group of operators.
#[derive(Debug, Clone)]
pub struct SfNode {
    pub id: usize,
    /// Member nodes in topological order.
    pub nodes: Vec<NodeId>,
    /// Which pattern seeded the group.
    pub pattern: String,
}

/// Output of subgraph selection.
#[derive(Debug, Clone)]
pub struct Selection {
    pub sf_nodes: Vec<SfNode>,
    /// Compute nodes left to run bulk-synchronous.
    pub unfused: Vec<NodeId>,
}

impl Selection {
    /// Fraction of compute ops covered by sf-nodes (Table 2 "Coverage").
    pub fn coverage(&self, g: &Graph) -> f64 {
        let fused: usize = self.sf_nodes.iter().map(|s| s.nodes.len()).sum();
        let total = g.n_compute_ops();
        if total == 0 {
            0.0
        } else {
            fused as f64 / total as f64
        }
    }

    pub fn n_fused_ops(&self) -> usize {
        self.sf_nodes.iter().map(|s| s.nodes.len()).sum()
    }
}

/// Selection options.
#[derive(Debug, Clone)]
pub struct SelectOptions {
    /// Maximum operators per sf-node (queue footprint / co-residency cap).
    pub max_stages: usize,
    /// Minimum operators for a group to be worth a spatial pipeline.
    pub min_stages: usize,
}

impl Default for SelectOptions {
    fn default() -> Self {
        SelectOptions { max_stages: 24, min_stages: 2 }
    }
}

/// Run subgraph selection over `g` with the given pattern library.
pub fn select_subgraphs(g: &Graph, lib: &PatternLib, opts: &SelectOptions) -> Selection {
    let (letters, ids) = encode(g);
    let matches = lib.matches(&letters);

    // Greedy non-overlapping pick: matches are sorted (start asc, longest
    // first); take a match when it does not overlap anything taken.
    let mut taken: Vec<(usize, usize, &'static str)> = Vec::new();
    let mut covered = vec![false; letters.len()];
    for (s, e, name) in matches {
        if (s..e).any(|i| covered[i]) {
            continue;
        }
        for c in covered.iter_mut().take(e).skip(s) {
            *c = true;
        }
        taken.push((s, e, name));
    }
    taken.sort_by_key(|t| t.0);

    // Merge adjacent intervals when a data edge connects them (builds the
    // long pipelines the paper fuses in e.g. NeRF — 100% coverage).
    let mut merged: Vec<(usize, usize, String)> = Vec::new();
    for (s, e, name) in taken {
        if let Some(last) = merged.last_mut() {
            if last.1 == s && connected_across(g, &ids[last.0..last.1], &ids[s..e]) {
                last.1 = e;
                last.2 = format!("{}+{}", last.2, name);
                continue;
            }
        }
        merged.push((s, e, name.to_string()));
    }

    // Enforce contiguity and stage caps; split where violated.
    let mut sf_nodes = Vec::new();
    let mut fused_set: HashSet<NodeId> = HashSet::new();
    for (s, e, pattern) in merged {
        let nodes: Vec<NodeId> = ids[s..e].to_vec();
        for part in split_contiguous(g, &nodes, opts.max_stages) {
            if part.len() < opts.min_stages {
                continue;
            }
            fused_set.extend(part.iter().copied());
            sf_nodes.push(SfNode { id: sf_nodes.len(), nodes: part, pattern: pattern.clone() });
        }
    }

    let unfused: Vec<NodeId> = g
        .nodes()
        .iter()
        .filter(|n| n.op.is_compute() && !fused_set.contains(&n.id))
        .map(|n| n.id)
        .collect();
    Selection { sf_nodes, unfused }
}

/// Is there a direct data edge between the two node sets?
fn connected_across(g: &Graph, a: &[NodeId], b: &[NodeId]) -> bool {
    let aset: HashSet<NodeId> = a.iter().copied().collect();
    b.iter().any(|&nb| g.node(nb).inputs.iter().any(|i| aset.contains(i)))
}

/// Check the Tarnawski contiguity condition for `nodes`; split the group
/// at violations and at the `max_stages` cap. Each returned part is
/// contiguous and within cap.
fn split_contiguous(g: &Graph, nodes: &[NodeId], max_stages: usize) -> Vec<Vec<NodeId>> {
    let mut parts: Vec<Vec<NodeId>> = Vec::new();
    let mut current: Vec<NodeId> = Vec::new();
    for &n in nodes {
        current.push(n);
        if current.len() >= max_stages || violates_contiguity(g, &current) {
            if violates_contiguity(g, &current) {
                // The newest node introduced the re-entry: close the group
                // before it and start fresh.
                current.pop();
                if !current.is_empty() {
                    parts.push(std::mem::take(&mut current));
                }
                current.push(n);
            } else {
                parts.push(std::mem::take(&mut current));
            }
        }
    }
    if !current.is_empty() {
        parts.push(current);
    }
    parts
}

/// True if some path exits the set and re-enters it.
pub fn violates_contiguity(g: &Graph, nodes: &[NodeId]) -> bool {
    let set: HashSet<NodeId> = nodes.iter().copied().collect();
    let lo = nodes.iter().map(|n| n.0).min().unwrap_or(0);
    let hi = nodes.iter().map(|n| n.0).max().unwrap_or(0);
    // Only nodes inside the topo window can be on an exit-reenter path.
    // reach_from_set[v] = v is reachable from the set via nodes outside it.
    let mut reach = vec![false; hi + 1];
    for v in lo..=hi {
        let id = NodeId(v);
        if set.contains(&id) {
            continue;
        }
        let mut from_set = false;
        for &i in &g.node(id).inputs {
            if set.contains(&i) || (i.0 >= lo && i.0 <= hi && reach.get(i.0) == Some(&true)) {
                from_set = true;
                break;
            }
        }
        reach[v] = from_set;
        if from_set {
            // Does v feed back into the set?
            if g.consumers(id).iter().any(|c| set.contains(c)) {
                return true;
            }
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EwKind, GraphBuilder, GraphKind};

    fn mlp_graph(layers: usize) -> Graph {
        let mut b = GraphBuilder::new("mlp", GraphKind::Inference);
        let x = b.input(&[1024, 256], "x");
        let widths: Vec<usize> = (0..layers).map(|_| 256).collect();
        b.mlp(x, &widths, EwKind::Relu, false, "net");
        b.finish()
    }

    #[test]
    fn mlp_fully_selected() {
        let g = mlp_graph(4);
        let sel = select_subgraphs(&g, &PatternLib::standard(), &SelectOptions::default());
        assert_eq!(sel.sf_nodes.len(), 1);
        assert!((sel.coverage(&g) - 1.0).abs() < 1e-9, "coverage {}", sel.coverage(&g));
        assert!(sel.unfused.is_empty());
    }

    #[test]
    fn gather_breaks_selection() {
        let mut b = GraphBuilder::new("emb", GraphKind::Inference);
        let x = b.input(&[1024, 256], "x");
        let h = b.linear(x, 256, false, "pre");
        let a = b.relu(h, "act");
        let idx = b.input(&[1024], "idx");
        let e = b.gather(idx, 50_000, 64, "emb");
        let cat = b.concat(&[a, e], "cat");
        let _ = b.linear(cat, 128, false, "post");
        let g = b.finish();
        let sel = select_subgraphs(&g, &PatternLib::standard(), &SelectOptions::default());
        // Gather itself must never be fused.
        for sf in &sel.sf_nodes {
            for &n in &sf.nodes {
                assert!(!g.node(n).op.excluded_from_subgraphs());
            }
        }
        assert!(sel.coverage(&g) < 1.0);
    }

    #[test]
    fn contiguity_violation_detected() {
        // a -> b -> c and a -> x -> c with x outside the set {a,b,c}\{x}:
        // selecting {a, c} with b outside violates; {a,b,c} is fine.
        let mut b = GraphBuilder::new("t", GraphKind::Inference);
        let x = b.input(&[64, 64], "x");
        let a = b.linear(x, 64, false, "a");
        let mid = b.relu(a, "mid");
        let c = b.ew2(EwKind::Add, a, mid, "c");
        let g = b.finish();
        assert!(violates_contiguity(&g, &[a, c]));
        assert!(!violates_contiguity(&g, &[a, mid, c]));
    }

    #[test]
    fn max_stages_splits_groups() {
        let g = mlp_graph(32); // 63 compute ops
        let opts = SelectOptions { max_stages: 8, min_stages: 2 };
        let sel = select_subgraphs(&g, &PatternLib::standard(), &opts);
        assert!(sel.sf_nodes.len() > 1);
        for sf in &sel.sf_nodes {
            assert!(sf.nodes.len() <= 8);
        }
        assert!(sel.coverage(&g) > 0.9);
    }

    #[test]
    fn selection_is_deterministic() {
        let g = mlp_graph(6);
        let a = select_subgraphs(&g, &PatternLib::standard(), &SelectOptions::default());
        let b = select_subgraphs(&g, &PatternLib::standard(), &SelectOptions::default());
        let na: Vec<_> = a.sf_nodes.iter().map(|s| s.nodes.clone()).collect();
        let nb: Vec<_> = b.sf_nodes.iter().map(|s| s.nodes.clone()).collect();
        assert_eq!(na, nb);
    }
}
