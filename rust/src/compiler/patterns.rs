//! §5.1 pattern library: "essentially a set of regular expressions that
//! express patterns including those seen in Figure 2", matched against the
//! deterministic topological linearization of the captured graph.
//!
//! Each compute node is encoded as one letter; a pattern is a regex over
//! the letter string of *selectable* nodes (excluded nodes — gathers,
//! scatters — act as hard separators, exactly the paper's exclusion
//! rules). Patterns are compiled by the in-crate [`super::relite`] engine
//! (the `regex` crate is unavailable offline).

use super::relite::Regex;
use crate::graph::{Graph, Node, NodeId, OpKind};

/// One-letter encoding of an operator for pattern matching.
pub fn letter(node: &Node) -> char {
    match &node.op {
        OpKind::Matmul { .. } => 'M',
        OpKind::Elementwise(_) => 'E',
        OpKind::Reduce { .. } => 'R',
        OpKind::Softmax => 'S',
        OpKind::LayerNorm => 'L',
        OpKind::Concat { .. } => 'C',
        OpKind::Interaction { .. } => 'I',
        OpKind::Loss => 'O',
        OpKind::OptimizerUpdate => 'U',
        OpKind::Gather { .. } => 'G',
        OpKind::Scatter => 'X',
        OpKind::Input | OpKind::Param | OpKind::Queue { .. } => '_',
    }
}

/// A named subgraph pattern.
#[derive(Debug, Clone)]
pub struct Pattern {
    pub name: &'static str,
    pub regex: Regex,
}

impl Pattern {
    fn new(name: &'static str, re: &str) -> Self {
        Pattern { name, regex: Regex::new(re).expect("pattern regex") }
    }
}

/// The pattern library. "Adding new patterns is a trivial task of adding
/// to our pattern library" — push another entry.
#[derive(Debug, Clone)]
pub struct PatternLib {
    pub patterns: Vec<Pattern>,
}

impl PatternLib {
    /// Patterns covering the paper's Fig 2 archetypes plus the composites
    /// its five applications exhibit (MLP chains, attention blocks,
    /// concat-fed MLPs, normalization-led blocks, gradient pipelines).
    pub fn standard() -> Self {
        PatternLib {
            patterns: vec![
                // Attention block: QKV projections, rope, score GEMM,
                // softmax, context GEMM, output projection.
                Pattern::new("attention", r"M+E*M?E*MS[ME]+"),
                // Fig 2(a): linear chains with elementwise between —
                // MLPs / transformer FFNs, optionally concat- or norm-led,
                // optionally ending in loss.
                Pattern::new("mlp_chain", r"[LC]?M(E+M)+E*O?"),
                // GEMM + epilogue elementwise (+ optional reduce tail).
                Pattern::new("gemm_epilogue", r"[LC]?ME+R?O?"),
                // Fig 2(c): multicast — elementwise grad feeding two GEMMs
                // (+ batch-reduce bias grads, Fig 2(b)).
                Pattern::new("grad_multicast", r"E+M+R?M*R?"),
                // Fig 2(b): reduction pipelines (split-K / batch grads).
                Pattern::new("reduce_tree", r"[ME]+R+[EU]*"),
                // Normalization-led block (layernorm/softmax + GEMMs).
                Pattern::new("norm_block", r"[LS][ME]+"),
                // Elementwise + optimizer tail (training epilogues).
                Pattern::new("ew_chain", r"E{2,}[RUO]*"),
                // Interaction-centered block (DLRM).
                Pattern::new("interaction_block", r"[CE]*I[ME]*"),
                // Pure GEMM pair (back-to-back projections).
                Pattern::new("gemm_pair", r"MM+"),
            ],
        }
    }

    /// All candidate intervals `[start, end)` (in selectable-index space)
    /// matched by any pattern on `s`, labeled with the pattern name.
    pub fn matches(&self, s: &str) -> Vec<(usize, usize, &'static str)> {
        let mut out = Vec::new();
        for p in &self.patterns {
            for m in p.regex.find_iter(s) {
                if m.end() > m.start() + 1 {
                    out.push((m.start(), m.end(), p.name));
                }
            }
        }
        // Deterministic order: by start, then longest first.
        out.sort_by(|a, b| a.0.cmp(&b.0).then(b.1.cmp(&a.1)));
        out
    }
}

/// Encode the graph's compute nodes in topological order.
/// Returns `(letters, node_ids)` — excluded nodes are encoded as `'|'`
/// separators so no pattern can span them.
pub fn encode(g: &Graph) -> (String, Vec<NodeId>) {
    let mut s = String::new();
    let mut ids = Vec::new();
    for n in g.nodes() {
        if !n.op.is_compute() {
            continue;
        }
        if n.op.excluded_from_subgraphs() {
            s.push('|');
        } else {
            s.push(letter(n));
        }
        ids.push(n.id);
    }
    (s, ids)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, GraphKind};

    #[test]
    fn letters_cover_ops() {
        let mut b = GraphBuilder::new("t", GraphKind::Inference);
        let x = b.input(&[8, 16], "x");
        let y = b.linear(x, 16, false, "l");
        let _z = b.relu(y, "r");
        let g = b.finish();
        let (s, ids) = encode(&g);
        assert_eq!(s, "ME");
        assert_eq!(ids.len(), 2);
    }

    #[test]
    fn excluded_ops_are_separators() {
        let mut b = GraphBuilder::new("t", GraphKind::Inference);
        let idx = b.input(&[128], "idx");
        let e = b.gather(idx, 1000, 64, "emb");
        let y = b.linear(e, 64, false, "l");
        let _ = b.relu(y, "r");
        let g = b.finish();
        let (s, _) = encode(&g);
        assert_eq!(s, "|ME");
    }

    #[test]
    fn mlp_chain_matches() {
        let lib = PatternLib::standard();
        let ms = lib.matches("MEMEMEM");
        assert!(ms.iter().any(|&(s, e, n)| s == 0 && e == 7 && n == "mlp_chain"), "{ms:?}");
    }

    #[test]
    fn attention_matches() {
        let lib = PatternLib::standard();
        // q,k,v GEMMs, 2 rope, score GEMM, softmax, ctx GEMM, out GEMM
        let ms = lib.matches("MMMEEMSMM");
        assert!(ms.iter().any(|&(s, e, _)| s == 0 && e == 9), "{ms:?}");
    }

    #[test]
    fn separator_blocks_span() {
        let lib = PatternLib::standard();
        let ms = lib.matches("ME|ME");
        assert!(ms.iter().all(|&(s, e, _)| !(s < 2 && e > 3)), "{ms:?}");
    }

    #[test]
    fn grad_multicast_matches() {
        let lib = PatternLib::standard();
        // act-grad ew feeding dgrad + wgrad GEMMs + bias reduce
        let ms = lib.matches("EMMR");
        assert!(ms.iter().any(|&(s, e, n)| s == 0 && e == 4 && n == "grad_multicast"), "{ms:?}");
    }
}
