//! Lowering: turn selected + designed + balanced sf-nodes into simulator
//! [`PipelineDesc`]s and a whole-application execution plan — the backend
//! half of Fig 7's compiler flow.

use super::load_balance::{balance, stage_work, BalancedPipeline, StageWork};
use super::patterns::PatternLib;
use super::pipeline::{design_pipeline, PipelineSpec};
use super::subgraph::{select_subgraphs, SelectOptions, Selection, SfNode};
use crate::graph::{Graph, NodeId, OpKind};
use crate::perfmodel::{self, IoPlacement, Loc};
use crate::sim::{GpuConfig, KernelDesc, PipelineDesc, QueueDesc, StageDesc};
use anyhow::Result;
use std::collections::HashMap;

/// Streamed tiles per sf-node pass: bounds.
pub const MIN_TILES: usize = 4;
pub const MAX_TILES: usize = 1024;
/// Fraction of L2 the queue set may occupy (the rest stays cache).
pub const L2_QUEUE_BUDGET: f64 = 0.6;
/// Queue payload ceiling — paper operates queues at ~64-256 KB payloads.
pub const MAX_PAYLOAD: usize = 256 * 1024;

/// A fully lowered sf-node, ready to simulate.
#[derive(Debug, Clone)]
pub struct LoweredPipeline {
    pub balanced: BalancedPipeline,
    pub desc: PipelineDesc,
    /// Graph nodes covered (for coverage / reporting).
    pub nodes: Vec<NodeId>,
}

/// One step of the application execution plan, in topological order.
#[derive(Debug, Clone)]
pub enum PlanItem {
    /// Run a single operator bulk-synchronously.
    Bsp(NodeId),
    /// Run a spatial pipeline (index into `CompiledApp::pipelines`).
    Pipeline(usize),
}

/// Compiler output for one application graph.
#[derive(Debug, Clone)]
pub struct CompiledApp {
    pub selection: Selection,
    pub pipelines: Vec<LoweredPipeline>,
    pub plan: Vec<PlanItem>,
}

impl CompiledApp {
    pub fn n_fused_ops(&self) -> usize {
        self.pipelines.iter().map(|p| p.nodes.len()).sum()
    }
}

/// I/O placement of `nid` when executed inside `sf` with `stage_of`
/// mapping members to stages.
pub fn dataflow_io(
    g: &Graph,
    nid: NodeId,
    stage_of: &HashMap<NodeId, usize>,
) -> IoPlacement {
    let node = g.node(nid);
    let my_stage = stage_of.get(&nid).copied();
    let ins = node
        .inputs
        .iter()
        .map(|i| {
            if matches!(g.node(*i).op, OpKind::Param) {
                // Weights always stream from DRAM (read once per pass).
                Loc::Dram
            } else {
                match (stage_of.get(i), my_stage) {
                    (Some(ps), Some(ms)) if *ps == ms => Loc::Smem, // epilogue-fused
                    (Some(_), Some(_)) => Loc::L2Queue,            // queue hop
                    _ => Loc::Dram,                                // enters the sf-node
                }
            }
        })
        .collect();
    // Output: queue if all consumers are inside the sf-node; DRAM if any
    // consumer is outside (or none — graph output). Same-stage consumers
    // keep the value in smem.
    let consumers = g.consumers(nid);
    let out = if consumers.is_empty() {
        Loc::Dram
    } else if consumers.iter().all(|c| stage_of.contains_key(c)) {
        if consumers
            .iter()
            .all(|c| stage_of.get(c) == my_stage.as_ref())
        {
            Loc::Smem
        } else {
            Loc::L2Queue
        }
    } else {
        Loc::Dram
    };
    IoPlacement { ins, out }
}

/// Queue entries for an edge: the paper instantiates one double-buffered
/// queue per communicating CTA pair (54 queues for 108 CTAs); the
/// simulator models an edge as one logical queue whose capacity is the
/// aggregate of those per-pair queues.
fn edge_entries(consumer_ctas: usize) -> usize {
    2 * consumer_ctas.max(1)
}

/// Choose the streamed tile count for a pipeline: start from the anchor
/// output's row tiles, keep every CTA fed with several tiles (bounding
/// fill/drain overhead), then refine until every queue payload fits the
/// paper's operating range and the total footprint fits in L2.
fn choose_tiles(
    g: &Graph,
    spec: &PipelineSpec,
    cfg: &GpuConfig,
    alloc: &[usize],
) -> usize {
    let anchor = g.node(spec.stages[0].nodes[0]);
    let rows = anchor.out.shape.leading();
    let max_alloc = alloc.iter().copied().max().unwrap_or(1);
    let mut tiles = (rows / perfmodel::GEMM_TILE).clamp(MIN_TILES, MAX_TILES);
    // ≥8 tiles per CTA so pipeline fill/drain and tile-count quantization
    // stay a small fraction of the run.
    tiles = tiles.max((8 * max_alloc).min(MAX_TILES));
    for _ in 0..12 {
        let worst_payload = spec
            .edges
            .iter()
            .map(|e| g.node(e.producer_node).out.bytes() / tiles)
            .max()
            .unwrap_or(0);
        let footprint: usize = spec
            .edges
            .iter()
            .map(|e| {
                QueueDesc {
                    payload_bytes: g.node(e.producer_node).out.bytes() / tiles,
                    entries: edge_entries(alloc[e.to_stage]),
                    memory_backed: e.to_stage - e.from_stage >= 2,
                }
                .footprint_bytes()
            })
            .sum();
        if (worst_payload > MAX_PAYLOAD || footprint * 2 > cfg.l2_capacity) && tiles < MAX_TILES {
            tiles = (tiles * 2).min(MAX_TILES);
        } else {
            break;
        }
    }
    tiles
}

/// Lower one sf-node end to end: design → placement → balance → descs.
pub fn lower_sf_node(g: &Graph, sf: &SfNode, cfg: &GpuConfig) -> Result<LoweredPipeline> {
    let spec = design_pipeline(g, sf);
    let stage_of: HashMap<NodeId, usize> = spec
        .stages
        .iter()
        .enumerate()
        .flat_map(|(i, s)| s.nodes.iter().map(move |&n| (n, i)))
        .collect();

    let works: Vec<StageWork> = spec
        .stages
        .iter()
        .map(|s| stage_work(g, s, |nid| dataflow_io(g, nid, &stage_of)))
        .collect();
    let balanced = balance(&spec, &works, cfg)?;

    let tiles = choose_tiles(g, &spec, cfg, &balanced.alloc);

    // Edge kinds: adjacent edges are double-buffered ring queues; edges
    // that skip ≥2 stages (fork-join residuals, multicast to a distant
    // consumer) are *memory-backed* — the producer writes the whole
    // intermediate and the consumer reads it as ordinary memory ("a CTA
    // is free to read any other values from memory", §4), modeled as an
    // unbounded token queue whose traffic is already accounted in the
    // stage's L2 bytes.
    let mut queues: Vec<QueueDesc> = spec
        .edges
        .iter()
        .map(|e| {
            let payload = (g.node(e.producer_node).out.bytes() / tiles).max(256);
            if e.to_stage - e.from_stage >= 2 {
                QueueDesc { payload_bytes: payload, entries: tiles, memory_backed: true }
            } else {
                QueueDesc {
                    payload_bytes: payload,
                    entries: edge_entries(balanced.alloc[e.to_stage]),
                    memory_backed: false,
                }
            }
        })
        .collect();
    // Fit the bounded queues into the L2 budget by halving entry counts
    // (CTA pairs share queues — more stalls, still correct). Floor of 2 =
    // double buffering. Memory-backed edges are exempt.
    let budget = (L2_QUEUE_BUDGET * cfg.l2_capacity as f64) as usize;
    let bounded: Vec<usize> = (0..queues.len()).filter(|&i| !queues[i].memory_backed).collect();
    for _ in 0..16 {
        let footprint: usize = bounded.iter().map(|&i| queues[i].footprint_bytes()).sum();
        if footprint <= budget || bounded.iter().all(|&i| queues[i].entries <= 2) {
            break;
        }
        for &i in &bounded {
            queues[i].entries = (queues[i].entries / 2).max(2);
        }
    }

    let stages: Vec<StageDesc> = spec
        .stages
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let w = &works[i];
            let a = balanced.alloc[i];
            let kernel = KernelDesc {
                name: format!("sf{}.stage{}.{}", sf.id, i, g.node(s.nodes[0]).name),
                class: s.class,
                n_ctas: a,
                flops_per_cta: w.flops / a as f64,
                dram_bytes_per_cta: w.dram_bytes / a as f64,
                l2_bytes_per_cta: w.l2_bytes / a as f64,
                smem_per_cta: perfmodel::smem_per_cta(g.node(s.nodes[0])),
                pipe_utilization: w.u,
            };
            StageDesc {
                kernel,
                n_tiles: tiles,
                input_queues: spec
                    .edges
                    .iter()
                    .enumerate()
                    .filter(|(_, e)| e.to_stage == i)
                    .map(|(qi, _)| qi)
                    .collect(),
                output_queues: spec
                    .edges
                    .iter()
                    .enumerate()
                    .filter(|(_, e)| e.from_stage == i)
                    .map(|(qi, _)| qi)
                    .collect(),
            }
        })
        .collect();

    let desc = PipelineDesc {
        name: format!("{}::sf{}({})", g.name, sf.id, spec.pattern),
        stages,
        queues,
    };
    Ok(LoweredPipeline { balanced, desc, nodes: sf.nodes.clone() })
}

/// Compile a whole application graph: select, design, balance, lower, and
/// emit the topological execution plan.
pub fn compile(g: &Graph, cfg: &GpuConfig, opts: &SelectOptions) -> Result<CompiledApp> {
    let selection = select_subgraphs(g, &PatternLib::standard(), opts);
    let mut pipelines = Vec::new();
    let mut first_member: HashMap<NodeId, usize> = HashMap::new();
    let mut members: HashMap<NodeId, usize> = HashMap::new();
    for sf in &selection.sf_nodes {
        let lp = lower_sf_node(g, sf, cfg)?;
        let idx = pipelines.len();
        first_member.insert(sf.nodes[0], idx);
        for &n in &sf.nodes {
            members.insert(n, idx);
        }
        pipelines.push(lp);
    }
    let mut plan = Vec::new();
    for n in g.nodes() {
        if !n.op.is_compute() {
            continue;
        }
        if let Some(&p) = first_member.get(&n.id) {
            plan.push(PlanItem::Pipeline(p));
        } else if !members.contains_key(&n.id) {
            plan.push(PlanItem::Bsp(n.id));
        }
    }
    Ok(CompiledApp { selection, pipelines, plan })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EwKind, GraphBuilder, GraphKind};
    use crate::sim::{Engine, SchedPolicy};

    fn ffn_graph() -> Graph {
        let mut b = GraphBuilder::new("ffn", GraphKind::Inference);
        let x = b.input(&[4096, 1024], "x");
        b.mlp(x, &[4096, 1024], EwKind::Gelu, false, "ffn");
        b.finish()
    }

    #[test]
    fn compile_produces_runnable_pipeline() {
        let g = ffn_graph();
        let cfg = GpuConfig::a100();
        let app = compile(&g, &cfg, &SelectOptions::default()).unwrap();
        assert_eq!(app.pipelines.len(), 1);
        let e = Engine::new(cfg, SchedPolicy::DualArbiter);
        let r = e.run_pipeline(&app.pipelines[0].desc).unwrap();
        assert!(r.elapsed_s > 0.0);
        assert!(r.flops > 0.0);
    }

    #[test]
    fn dataflow_reduces_dram_traffic() {
        let g = ffn_graph();
        let cfg = GpuConfig::a100();
        let app = compile(&g, &cfg, &SelectOptions::default()).unwrap();
        let p = &app.pipelines[0];
        let df_dram: f64 = p.desc.stages.iter().map(|s| s.kernel.total_dram_bytes()).sum();
        let bsp_dram: f64 = g
            .compute_nodes()
            .map(|n| perfmodel::bsp_kernel(n, &g, &cfg).total_dram_bytes())
            .sum();
        assert!(
            df_dram < 0.7 * bsp_dram,
            "dataflow {df_dram:.2e} vs bsp {bsp_dram:.2e}"
        );
    }

    #[test]
    fn queue_payloads_in_operating_range() {
        let g = ffn_graph();
        let cfg = GpuConfig::a100();
        let app = compile(&g, &cfg, &SelectOptions::default()).unwrap();
        for q in &app.pipelines[0].desc.queues {
            assert!(q.payload_bytes <= MAX_PAYLOAD, "{}", q.payload_bytes);
            // Aggregate of the per-CTA-pair double-buffered queues.
            assert!(q.entries >= 2 && q.entries % 2 == 0, "{}", q.entries);
        }
        assert!(app.pipelines[0].desc.queue_footprint() <= cfg.l2_capacity);
    }

    #[test]
    fn plan_orders_pipeline_and_bsp_items() {
        let mut b = GraphBuilder::new("mix", GraphKind::Inference);
        let idx = b.input(&[1024], "idx");
        let e = b.gather(idx, 10_000, 64, "emb"); // unfusable
        b.mlp(e, &[512, 512, 64], EwKind::Relu, false, "mlp");
        let g = b.finish();
        let cfg = GpuConfig::a100();
        let app = compile(&g, &cfg, &SelectOptions::default()).unwrap();
        assert!(matches!(app.plan[0], PlanItem::Bsp(_)), "gather first");
        assert!(app.plan.iter().any(|p| matches!(p, PlanItem::Pipeline(_))));
        // Every compute op appears exactly once across plan items.
        let bsp_count = app.plan.iter().filter(|p| matches!(p, PlanItem::Bsp(_))).count();
        let fused: usize = app.pipelines.iter().map(|p| p.nodes.len()).sum();
        assert_eq!(bsp_count + fused, g.n_compute_ops());
    }
}
