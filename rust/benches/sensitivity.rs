//! Bench: regenerate the §6 hardware-synergy study (2x SMs / 2x L2 BW
//! with DRAM fixed) — the paper's headline 47%/27% Kitsune gains vs
//! 18-26% for baseline execution.
use kitsune::apps;
use kitsune::bench::bench;
use kitsune::report;

fn main() {
    let cfgs = report::sensitivity_configs();
    let names: Vec<String> = cfgs.iter().map(|c| c.name.clone()).collect();
    for (title, suite) in [
        ("Inference", apps::inference_suite()),
        ("Training", apps::training_suite()),
    ] {
        let evals: Vec<_> = cfgs
            .iter()
            .map(|c| report::evaluate_suite(&suite, c).unwrap())
            .collect();
        println!("== {title} ==\n{}", report::sensitivity(&names, &evals));
    }
    bench("sensitivity/one-config-inference", 1, 3, || {
        report::evaluate_suite(&apps::inference_suite(), &cfgs[1]).unwrap()
    });
}
