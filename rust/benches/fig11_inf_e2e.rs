//! Bench: regenerate paper Fig 11 (inference end-to-end speedups) and
//! time the three-way evaluation per application.
use kitsune::apps;
use kitsune::bench::bench;
use kitsune::report;
use kitsune::sim::GpuConfig;

fn main() {
    let cfg = GpuConfig::a100();
    let suite = apps::inference_suite();
    let evals = report::evaluate_suite(&suite, &cfg).unwrap();
    println!(
        "{}",
        report::e2e_speedups("Fig 11. Inference end-to-end speedup over bulk-sync.", &evals)
    );
    for (name, g) in suite.iter() {
        bench(&format!("fig11/eval-{name}"), 1, 3, || {
            report::evaluate_app(name, g, &cfg).unwrap()
        });
    }
}
