//! Bench: the real host ring queue (the §4.1 primitive itself) —
//! SPSC/MPMC handoff rate and payload bandwidth, the host-level analog
//! of the paper's silicon queue microbenchmark.
use kitsune::bench::{bench, black_box};
use kitsune::queue::RingQueue;
use std::sync::Arc;
use std::thread;

fn spsc_throughput(payload_f32: usize, n_msgs: usize, capacity: usize) -> f64 {
    let q: Arc<RingQueue<Vec<f32>>> = RingQueue::with_capacity(capacity);
    let p = Arc::clone(&q);
    let t0 = std::time::Instant::now();
    let producer = thread::spawn(move || {
        let tile = vec![1.0f32; payload_f32];
        for _ in 0..n_msgs {
            p.push(tile.clone()).unwrap();
        }
        p.close();
    });
    let mut sum = 0.0f32;
    while let Some(v) = q.pop() {
        sum += v[0];
    }
    producer.join().unwrap();
    black_box(sum);
    let secs = t0.elapsed().as_secs_f64();
    (n_msgs * payload_f32 * 4) as f64 / secs
}

fn main() {
    println!("host ring queue bandwidth (SPSC, double-buffered cap=2 vs cap=8):");
    for payload in [256usize, 4096, 16384, 65536] {
        let bw2 = spsc_throughput(payload, 2000, 2);
        let bw8 = spsc_throughput(payload, 2000, 8);
        println!(
            "  payload {:>7}B  cap2 {:>8.1} MB/s   cap8 {:>8.1} MB/s",
            payload * 4,
            bw2 / 1e6,
            bw8 / 1e6
        );
    }
    bench("queue_host/handoff-64KB", 1, 10, || {
        spsc_throughput(16384, 500, 8)
    });
    bench("queue_host/handoff-1KB", 1, 10, || spsc_throughput(256, 2000, 8));
}
