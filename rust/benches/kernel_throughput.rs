//! Interpreter kernel throughput: GFLOP/s of the blocked/parallel matmul
//! micro-kernels against the retained scalar reference, transpose
//! specializations, and fused-vs-unfused elementwise chains.
//!
//! Prints a table and writes `BENCH_interp.kernel.part` (plain
//! `key value` lines) at the repo root. `make bench` runs this first and
//! `session_throughput` second — the latter folds the part file into the
//! final `BENCH_interp.json`.
//!
//! Run: `cargo bench --bench kernel_throughput` (`BENCH_SMOKE=1` for the
//! CI smoke variant).

use kitsune::bench::{artifact_root, smoke};
use kitsune::runtime::interp::{Act, Instr, Program};
use kitsune::runtime::{simd, Rng, Tensor};
use std::fmt::Write as _;
use std::time::Instant;

fn tensor(rng: &mut Rng, dims: &[usize]) -> Tensor {
    let numel: usize = dims.iter().product();
    Tensor::new(dims.to_vec(), (0..numel).map(|_| rng.normal()).collect()).unwrap()
}

/// Seconds per iteration, doubling the iteration count until the timed
/// region is long enough to trust.
fn time_per_iter(min_time_s: f64, mut f: impl FnMut()) -> f64 {
    let mut iters = 1usize;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t0.elapsed().as_secs_f64();
        if dt >= min_time_s || iters >= 1 << 22 {
            return dt / iters as f64;
        }
        iters *= 2;
    }
}

fn main() -> anyhow::Result<()> {
    let smoke = smoke();
    let min_time = if smoke { 0.02 } else { 0.25 };
    let mut rng = Rng::new(0xD00D);
    let mut part = String::new();

    println!("interpreter kernel throughput (optimized engine vs scalar reference):");

    // Square matmuls across the serial/parallel threshold.
    let sizes: &[usize] = if smoke { &[64, 128] } else { &[64, 128, 256, 384] };
    for &n in sizes {
        let p = Program { n_inputs: 2, instrs: vec![Instr::Matmul { a: 0, b: 1 }], outputs: vec![2] };
        let a = tensor(&mut rng, &[n, n]);
        let b = tensor(&mut rng, &[n, n]);
        let inputs = [a, b];
        let flops = 2.0 * (n * n * n) as f64;
        let opt_s = time_per_iter(min_time, || {
            std::hint::black_box(p.run(&inputs).unwrap());
        });
        let ref_s = time_per_iter(min_time, || {
            std::hint::black_box(p.run_reference(&inputs).unwrap());
        });
        let (gf_opt, gf_ref) = (flops / opt_s / 1e9, flops / ref_s / 1e9);
        println!(
            "  matmul {n:>4}^3   optimized {gf_opt:>7.2} GFLOP/s   reference {gf_ref:>7.2} GFLOP/s   {:.2}x",
            gf_opt / gf_ref.max(1e-12)
        );
        let _ = writeln!(part, "matmul_{n}_gflops {gf_opt:.4}");
        let _ = writeln!(part, "matmul_{n}_ref_gflops {gf_ref:.4}");
        let _ = writeln!(part, "matmul_{n}_speedup {:.4}", gf_opt / gf_ref.max(1e-12));
    }

    // SIMD dispatch on the matmul micro-kernel: the same blocked/parallel
    // engine with the vector layer forced off (the exact pre-SIMD scalar
    // kernels, what `KITSUNE_SIMD=0` runs) vs the runtime-dispatched
    // vector path. Pure kernel-ISA comparison: same partitioning, same
    // fusion, same buffers.
    let simd_n = if smoke { 128 } else { 256 };
    let simd_speedup = {
        let p = Program { n_inputs: 2, instrs: vec![Instr::Matmul { a: 0, b: 1 }], outputs: vec![2] };
        let inputs = [tensor(&mut rng, &[simd_n, simd_n]), tensor(&mut rng, &[simd_n, simd_n])];
        let flops = 2.0 * (simd_n * simd_n * simd_n) as f64;
        let prev = simd::vector_enabled();
        simd::set_vector_enabled(false);
        let scalar_s = time_per_iter(min_time, || {
            std::hint::black_box(p.run(&inputs).unwrap());
        });
        simd::set_vector_enabled(true);
        let vec_s = time_per_iter(min_time, || {
            std::hint::black_box(p.run(&inputs).unwrap());
        });
        simd::set_vector_enabled(prev);
        let (gf_vec, gf_scalar) = (flops / vec_s / 1e9, flops / scalar_s / 1e9);
        let speedup = gf_vec / gf_scalar.max(1e-12);
        println!(
            "  simd matmul {simd_n:>3}^3 [{}]  vector {gf_vec:>7.2} GFLOP/s   scalar {gf_scalar:>7.2} GFLOP/s   {speedup:.2}x",
            simd::dispatch_label()
        );
        let _ = writeln!(part, "simd_matmul_{simd_n}_gflops {gf_vec:.4}");
        let _ = writeln!(part, "simd_matmul_{simd_n}_scalar_gflops {gf_scalar:.4}");
        let _ = writeln!(part, "simd_speedup {speedup:.4}");
        speedup
    };
    // Acceptance gate: the vector micro-kernel must clearly beat the
    // scalar one. Only meaningful where an FMA vector ISA actually
    // dispatched, and skipped in the CI smoke tier (timings too short
    // to trust).
    if !smoke && simd::fused_madd() {
        assert!(
            simd_speedup > 1.5,
            "simd matmul micro-kernel speedup {simd_speedup:.2}x <= 1.5x on an FMA host"
        );
    }

    // Transpose specializations (the train-step gradient GEMMs) at one
    // representative size.
    let tn_size = if smoke { 96 } else { 256 };
    for (tag, instr, da, db) in [
        ("tn", Instr::MatmulTn { a: 0, b: 1 }, [tn_size, tn_size], [tn_size, tn_size]),
        ("nt", Instr::MatmulNt { a: 0, b: 1 }, [tn_size, tn_size], [tn_size, tn_size]),
    ] {
        let p = Program { n_inputs: 2, instrs: vec![instr], outputs: vec![2] };
        let inputs = [tensor(&mut rng, &da), tensor(&mut rng, &db)];
        let flops = 2.0 * (tn_size * tn_size * tn_size) as f64;
        let opt_s = time_per_iter(min_time, || {
            std::hint::black_box(p.run(&inputs).unwrap());
        });
        let ref_s = time_per_iter(min_time, || {
            std::hint::black_box(p.run_reference(&inputs).unwrap());
        });
        let (gf_opt, gf_ref) = (flops / opt_s / 1e9, flops / ref_s / 1e9);
        println!(
            "  matmul_{tag} {tn_size:>3}^3 optimized {gf_opt:>7.2} GFLOP/s   reference {gf_ref:>7.2} GFLOP/s   {:.2}x",
            gf_opt / gf_ref.max(1e-12)
        );
        let _ = writeln!(part, "matmul_{tag}_{tn_size}_gflops {gf_opt:.4}");
        let _ = writeln!(part, "matmul_{tag}_{tn_size}_speedup {:.4}", gf_opt / gf_ref.max(1e-12));
    }

    // Elementwise fusion in isolation: AddBias→Gelu as two instructions
    // vs the fused BiasAct, both on the optimized engine.
    let (rows, cols) = if smoke { (512, 128) } else { (4096, 256) };
    let unfused = Program {
        n_inputs: 2,
        instrs: vec![Instr::AddBias { a: 0, bias: 1 }, Instr::Gelu { a: 2 }],
        outputs: vec![3],
    };
    let fused = Program {
        n_inputs: 2,
        instrs: vec![Instr::BiasAct { a: 0, bias: 1, act: Act::Gelu }],
        outputs: vec![2],
    };
    let inputs = [tensor(&mut rng, &[rows, cols]), tensor(&mut rng, &[cols])];
    let unfused_s = time_per_iter(min_time, || {
        std::hint::black_box(unfused.run(&inputs).unwrap());
    });
    let fused_s = time_per_iter(min_time, || {
        std::hint::black_box(fused.run(&inputs).unwrap());
    });
    println!(
        "  bias+gelu {rows}x{cols}   fused {:.3} ms   unfused {:.3} ms   {:.2}x",
        fused_s * 1e3,
        unfused_s * 1e3,
        unfused_s / fused_s.max(1e-12)
    );
    let _ = writeln!(part, "ew_fusion_speedup {:.4}", unfused_s / fused_s.max(1e-12));

    let out = artifact_root().join("BENCH_interp.kernel.part");
    std::fs::write(&out, part)?;
    println!("kernel metrics staged at {}", out.display());
    Ok(())
}
