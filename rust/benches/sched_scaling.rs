//! Scheduler scaling: how the one shared work-stealing pool
//! (`kitsune::sched`) performs under its three tenants as worker count
//! grows —
//!
//! * GEMM GFLOP/s (row-panel fork-join inside one matmul kernel);
//! * warm pipeline tiles/sec (`PipelineService` stage pumps), against a
//!   hand-rolled dedicated-OS-thread stage pool over the *same* lowered
//!   stages — the architecture the pumps replaced;
//! * DAG training steps/sec at 1 vs 2 pumps per stage.
//!
//! Numbers are measured on whatever host runs the bench —
//! `host_parallelism` is recorded so a 1-core container's flat scaling
//! reads as what it is, not a regression.
//!
//! Writes `BENCH_sched.json` at the repo root.
//! Run: `cargo bench --bench sched_scaling` (`BENCH_SMOKE=1` for CI).

use kitsune::bench::{artifact_root, smoke};
use kitsune::compiler::{compile, SelectOptions};
use kitsune::queue::{PushError, RingQueue};
use kitsune::runtime::interp::{
    matmul_par_threshold, set_matmul_par_threshold, Instr, Program,
};
use kitsune::runtime::{bound_executable, ArtifactStore, Rng, Tensor};
use kitsune::sched::{self, Scheduler};
use kitsune::session::{lower_app, nerf_trunk_graph, LowerOptions, PipelineService, Session};
use kitsune::sim::GpuConfig;
use kitsune::train::OptimizerKind;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

const TILE_ROWS: usize = 64;
const ROWS: usize = 2048;
const IN_DIM: usize = 60;
const HIDDEN: usize = 64;
const OUT_DIM: usize = 3;

fn tensor(rng: &mut Rng, dims: &[usize]) -> Tensor {
    let numel: usize = dims.iter().product();
    Tensor {
        dims: dims.to_vec(),
        data: (0..numel).map(|_| rng.normal()).collect(),
        prec: kitsune::runtime::Precision::F32,
    }
}

fn make_tiles(n: usize, seed: u64, rows: usize, dim: usize) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| Tensor {
            dims: vec![rows, dim],
            data: (0..rows * dim).map(|_| rng.normal()).collect(),
            prec: kitsune::runtime::Precision::F32,
        })
        .collect()
}

fn time_per_iter(min_time_s: f64, mut f: impl FnMut()) -> f64 {
    let mut iters = 1usize;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t0.elapsed().as_secs_f64();
        if dt >= min_time_s || iters >= 1 << 22 {
            return dt / iters as f64;
        }
        iters *= 2;
    }
}

/// Ascending deduplicated worker counts: 1, 2, 4 and the host's
/// available parallelism.
fn worker_counts(host: usize) -> Vec<usize> {
    let mut ws = vec![1usize, 2, 4, host.max(1)];
    ws.sort_unstable();
    ws.dedup();
    ws
}

/// The dedicated-thread baseline the cooperative pumps replaced: one OS
/// thread per stage worker, blocking pops, countdown-latch close. Runs
/// `batches x tiles_per_batch` tiles through the same lowered store and
/// returns steady-state tiles/sec (one unmeasured priming batch).
fn dedicated_pool_tiles_per_sec(
    store: &Arc<ArtifactStore>,
    pipeline: &kitsune::coordinator::SpatialPipeline,
    tiles_per_batch: usize,
    batches: usize,
    rows: usize,
    dim: usize,
) -> anyhow::Result<f64> {
    type Tile = (usize, Tensor);
    let n_stages = pipeline.stages.len();
    let queues: Vec<Arc<RingQueue<Tile>>> = (0..=n_stages)
        .map(|_| RingQueue::with_capacity(pipeline.queue_capacity))
        .collect();
    let mut elapsed = 0.0f64;
    std::thread::scope(|scope| -> anyhow::Result<()> {
        for (si, stage) in pipeline.stages.iter().enumerate() {
            let remaining = Arc::new(AtomicUsize::new(stage.workers));
            for _ in 0..stage.workers {
                let in_q = Arc::clone(&queues[si]);
                let out_q = Arc::clone(&queues[si + 1]);
                let remaining = Arc::clone(&remaining);
                let entry = stage.entry.clone();
                let weights = Arc::clone(&stage.weights);
                let store = Arc::clone(store);
                scope.spawn(move || {
                    while let Some((seq, tile)) = in_q.pop() {
                        let mut args: Vec<&Tensor> = Vec::with_capacity(1 + weights.len());
                        args.push(&tile);
                        args.extend(weights.iter());
                        let out = store
                            .run_f32_ref(&entry, &args)
                            .expect("baseline stage kernel")
                            .remove(0);
                        if let Err(PushError::Closed(_)) = out_q.push((seq, out)) {
                            break;
                        }
                    }
                    if remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                        out_q.close();
                    }
                });
            }
        }
        // Feed batches from this thread, draining outputs as we go so
        // bounded rings can never wedge the feeder.
        let src = &queues[0];
        let out_q = &queues[n_stages];
        let mut run_batch = |seed: u64| -> anyhow::Result<()> {
            let mut got = 0usize;
            for (seq, t) in make_tiles(tiles_per_batch, seed, rows, dim).into_iter().enumerate()
            {
                src.push((seq, t)).map_err(|_| anyhow::anyhow!("source closed early"))?;
                while out_q.try_pop().is_ok() {
                    got += 1;
                }
            }
            while got < tiles_per_batch {
                out_q.pop().ok_or_else(|| anyhow::anyhow!("pipeline closed early"))?;
                got += 1;
            }
            Ok(())
        };
        run_batch(999)?; // prime
        let t0 = Instant::now();
        for b in 0..batches {
            run_batch(b as u64)?;
        }
        elapsed = t0.elapsed().as_secs_f64();
        queues[0].close();
        Ok(())
    })?;
    Ok((tiles_per_batch * batches) as f64 / elapsed.max(1e-12))
}

fn main() -> anyhow::Result<()> {
    let smoke = smoke();
    let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let counts = worker_counts(host);
    let min_time = if smoke { 0.02 } else { 0.25 };
    println!("scheduler scaling (host parallelism: {host}):");

    // ---- GEMM row-panel fork-join -------------------------------------
    // Force the parallel path regardless of size, then restore.
    let n = if smoke { 128usize } else { 384 };
    let mut rng = Rng::new(0xACE5);
    let p = Program { n_inputs: 2, instrs: vec![Instr::Matmul { a: 0, b: 1 }], outputs: vec![2] };
    let inputs = [tensor(&mut rng, &[n, n]), tensor(&mut rng, &[n, n])];
    let flops = 2.0 * (n * n * n) as f64;
    let saved_threshold = matmul_par_threshold();
    set_matmul_par_threshold(1);
    let mut gemm_gflops: Vec<(usize, f64)> = Vec::new();
    for &w in &counts {
        let s = Scheduler::with_workers(w);
        let secs = sched::with_scheduler(&s, || {
            time_per_iter(min_time, || {
                std::hint::black_box(p.run(&inputs).unwrap());
            })
        });
        s.shutdown();
        let gf = flops / secs / 1e9;
        // The kernel caps its own fan-out at 4 panels; more workers only
        // help the other pool tenants.
        println!("  gemm {n}^3 @ {w} workers: {gf:>7.2} GFLOP/s");
        gemm_gflops.push((w, gf));
    }
    set_matmul_par_threshold(saved_threshold);

    // ---- warm pipeline stage pumps vs dedicated threads ---------------
    let (tiles_per_batch, batches) = if smoke { (8usize, 2usize) } else { (32, 6) };
    let g = nerf_trunk_graph(ROWS, IN_DIM, HIDDEN, OUT_DIM);
    let app = compile(&g, &GpuConfig::a100(), &SelectOptions::default())?;
    let low = lower_app(
        &g,
        &app,
        &LowerOptions { tile_rows: Some(TILE_ROWS), ..LowerOptions::default() },
    )?;
    let execs = low
        .entries
        .iter()
        .map(|(spec, program, weights)| {
            (spec.clone(), bound_executable(spec.name.clone(), program.clone(), weights.clone()))
        })
        .collect();
    let store = Arc::new(ArtifactStore::from_executables("sched-scaling", execs));

    let dedicated_tps = dedicated_pool_tiles_per_sec(
        &store,
        &low.pipeline,
        tiles_per_batch,
        batches,
        low.tile_rows,
        low.in_dim,
    )?;
    println!("  pipeline dedicated threads:      {dedicated_tps:>8.1} tiles/s");

    let mut pipe_tps: Vec<(usize, f64)> = Vec::new();
    for &w in &counts {
        let s = Scheduler::with_workers(w);
        let svc = sched::with_scheduler(&s, || {
            PipelineService::start(
                Arc::clone(&store),
                &low.pipeline,
                vec![low.tile_rows, low.in_dim],
                Arc::new(kitsune::fault::FaultPlan::new()),
            )
        })?;
        svc.submit(make_tiles(tiles_per_batch, 999, low.tile_rows, low.in_dim))?.wait()?;
        let t0 = Instant::now();
        for b in 0..batches {
            let out = svc
                .submit(make_tiles(tiles_per_batch, b as u64, low.tile_rows, low.in_dim))?
                .wait()?;
            assert_eq!(out.outputs.len(), tiles_per_batch);
        }
        let tps = (tiles_per_batch * batches) as f64 / t0.elapsed().as_secs_f64().max(1e-12);
        svc.shutdown();
        s.shutdown();
        println!(
            "  pipeline pumps @ {w} workers:     {tps:>8.1} tiles/s  ({:.2}x vs dedicated)",
            tps / dedicated_tps.max(1e-12)
        );
        pipe_tps.push((w, tps));
    }

    // ---- DAG training: pumps per stage --------------------------------
    let nerf_cfg = if smoke {
        kitsune::apps::nerf::NerfConfig {
            batch: 128,
            pos_enc: 8,
            dir_enc: 4,
            hidden: 16,
            depth: 3,
            skip_at: 1,
        }
    } else {
        kitsune::apps::nerf::NerfConfig {
            batch: 512,
            pos_enc: 16,
            dir_enc: 8,
            hidden: 32,
            depth: 4,
            skip_at: 2,
        }
    };
    let steps = if smoke { 3usize } else { 10 };
    let mut train_sps: Vec<(usize, f64)> = Vec::new();
    for pumps in [1usize, 2] {
        let session = Session::builder()
            .graph(kitsune::apps::nerf::training(&nerf_cfg))
            .tile_rows(nerf_cfg.batch / 16)
            .train_workers(pumps)
            .build()?;
        let mut trainer = session.trainer_with(OptimizerKind::sgd(1e-2))?;
        let batch = session.make_train_batch(0xBE9C)?;
        trainer.step(&batch)?; // prime
        let t0 = Instant::now();
        for _ in 0..steps {
            trainer.step(&batch)?;
        }
        let sps = steps as f64 / t0.elapsed().as_secs_f64().max(1e-12);
        session.shutdown();
        println!("  training @ {pumps} pumps/stage:     {sps:>8.2} steps/s");
        train_sps.push((pumps, sps));
    }
    let train_speedup = train_sps[1].1 / train_sps[0].1.max(1e-12);
    println!("  training 2-pump over 1-pump:     {train_speedup:.2}x");

    // ---- BENCH_sched.json ---------------------------------------------
    let root = artifact_root();
    let mut json = String::from("{\n");
    let _ = writeln!(json, "  \"bench\": \"sched_scaling\",");
    let _ = writeln!(json, "  \"smoke\": {smoke},");
    let _ = writeln!(json, "  \"host_parallelism\": {host},");
    let _ = writeln!(json, "  \"gemm\": {{");
    let _ = writeln!(json, "    \"n\": {n},");
    for (i, (w, gf)) in gemm_gflops.iter().enumerate() {
        let comma = if i + 1 < gemm_gflops.len() { "," } else { "" };
        let _ = writeln!(json, "    \"gflops_w{w}\": {gf:.3}{comma}");
    }
    let _ = writeln!(json, "  }},");
    let _ = writeln!(json, "  \"pipeline\": {{");
    let _ = writeln!(json, "    \"tiles_per_batch\": {tiles_per_batch},");
    let _ = writeln!(json, "    \"batches\": {batches},");
    let _ = writeln!(json, "    \"dedicated_tiles_per_sec\": {dedicated_tps:.2},");
    for (i, (w, tps)) in pipe_tps.iter().enumerate() {
        let comma = if i + 1 < pipe_tps.len() { "," } else { "" };
        let _ = writeln!(json, "    \"pump_tiles_per_sec_w{w}\": {tps:.2}{comma}");
    }
    let _ = writeln!(json, "  }},");
    let _ = writeln!(json, "  \"train\": {{");
    let _ = writeln!(json, "    \"steps\": {steps},");
    for (w, sps) in &train_sps {
        let _ = writeln!(json, "    \"steps_per_sec_pumps{w}\": {sps:.3},");
    }
    let _ = writeln!(json, "    \"two_pump_over_one\": {train_speedup:.3}");
    let _ = writeln!(json, "  }}");
    json.push_str("}\n");
    let out_path = root.join("BENCH_sched.json");
    std::fs::write(&out_path, json)?;
    println!("scheduler scaling written to {}", out_path.display());
    Ok(())
}
