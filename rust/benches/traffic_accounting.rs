//! Dataflow traffic accounting: per-app off-chip-analog byte movement
//! under dataflow execution (source injection + sink drains + weight
//! re-reads) vs the serial bulk-sync oracle (which additionally stores
//! and re-loads every ring-queue intermediate), plus the telemetry
//! harness overhead probe (counters-only vs tracing-armed throughput).
//!
//! Writes `BENCH_traffic.json` at the repo root.
//! Run: `cargo bench --bench traffic_accounting` (`BENCH_SMOKE=1` for CI).

use kitsune::apps::{dlrm, nerf};
use kitsune::bench::{artifact_root, smoke};
use kitsune::runtime::Precision;
use kitsune::session::{nerf_trunk_graph, Session};
use kitsune::telemetry::TrafficSnapshot;
use std::fmt::Write as _;
use std::time::Instant;

struct AppTraffic {
    app: &'static str,
    mode: &'static str,
    tiles: u64,
    traffic: TrafficSnapshot,
}

/// Stream `reps` batches of tiles through the warm NeRF trunk at the
/// given storage precision and return the accumulated traffic classes —
/// edges are charged at storage width, so the bf16 leg moves half the
/// per-tile bytes of the f32 leg.
fn trunk_inference(
    reps: usize,
    prec: Precision,
    mode: &'static str,
) -> anyhow::Result<AppTraffic> {
    let session = Session::builder()
        .graph(nerf_trunk_graph(512, 60, 64, 3))
        .tile_rows(64)
        .workers(2)
        .precision(prec)
        .build()?;
    let tiles = session.make_tiles(16, 0xACC0)?;
    let mut n = 0u64;
    for _ in 0..reps {
        n += session.run(tiles.clone())?.outputs.len() as u64;
    }
    let traffic = session
        .telemetry()
        .expect("warm session registers telemetry")
        .traffic
        .snapshot();
    session.shutdown();
    Ok(AppTraffic { app: "nerf-trunk", mode, tiles: n, traffic })
}

/// Run `steps` training steps on a warm DAG pipeline and return the
/// accumulated traffic classes.
fn train_traffic(
    app: &'static str,
    graph: kitsune::graph::Graph,
    steps: usize,
) -> anyhow::Result<AppTraffic> {
    let session = Session::builder().graph(graph).tile_rows(16).build()?;
    let batch = session.make_train_batch(0xACC1)?;
    let mut trainer = session.trainer()?;
    let mut n = 0u64;
    for _ in 0..steps {
        n += trainer.step(&batch)?.tiles as u64;
    }
    let traffic = session
        .telemetry()
        .expect("warm DAG registers telemetry")
        .traffic
        .snapshot();
    session.shutdown();
    Ok(AppTraffic { app, mode: "training", tiles: n, traffic })
}

/// Telemetry-overhead probe: the same trunk workload with (a) the
/// always-on counters (production hot path) and (b) the span recorder
/// armed, which does strictly more work per tile — string allocation and
/// a mutex push per span — so it conservatively bounds the counter cost.
/// Must run *after* every traffic measurement: the trace sink latches on
/// and cannot be disarmed in-process.
fn telemetry_overhead(smoke: bool) -> anyhow::Result<(f64, f64, f64)> {
    let reps = if smoke { 4 } else { 16 };
    let measure = || -> anyhow::Result<f64> {
        let session = Session::builder()
            .graph(nerf_trunk_graph(512, 60, 64, 3))
            .tile_rows(64)
            .workers(2)
            .build()?;
        session.run(session.make_tiles(4, 1)?)?; // prime the kernels
        let tiles = session.make_tiles(32, 2)?;
        let t0 = Instant::now();
        let mut n = 0u64;
        for _ in 0..reps {
            n += session.run(tiles.clone())?.outputs.len() as u64;
        }
        let tps = n as f64 / t0.elapsed().as_secs_f64().max(1e-12);
        session.shutdown();
        Ok(tps)
    };
    let counters_tps = measure()?;
    let trace_path = std::env::temp_dir().join("kitsune_bench_overhead_trace.json");
    kitsune::telemetry::trace::enable(&trace_path)
        .ok_or_else(|| anyhow::anyhow!("trace sink latched off (KITSUNE_TRACE set but empty)"))?;
    let traced_tps = measure()?;
    let _ = std::fs::remove_file(&trace_path);
    Ok((counters_tps, traced_tps, counters_tps / traced_tps.max(1e-12) - 1.0))
}

fn main() -> anyhow::Result<()> {
    let smoke = smoke();
    let (inf_reps, steps) = if smoke { (2, 1) } else { (8, 4) };
    println!("dataflow traffic accounting ({inf_reps} inference reps, {steps} train steps):");

    let tiny_nerf = nerf::training(&nerf::NerfConfig {
        batch: 64,
        pos_enc: 8,
        dir_enc: 4,
        hidden: 16,
        depth: 3,
        skip_at: 1,
    });
    let dense_dlrm = dlrm::dense_training(&dlrm::DlrmConfig {
        batch: 64,
        dense_features: 8,
        bottom_mlp: vec![16, 8],
        top_mlp: vec![16, 1],
        ..dlrm::DlrmConfig::default()
    });

    let apps = vec![
        trunk_inference(inf_reps, Precision::F32, "inference")?,
        trunk_inference(inf_reps, Precision::Bf16, "inference-bf16")?,
        train_traffic("nerf", tiny_nerf, steps)?,
        train_traffic("dlrm-dense", dense_dlrm, steps)?,
    ];
    for a in &apps {
        let t = &a.traffic;
        println!(
            "  {:<12} {:<9} {:>6} tiles: dataflow {:>10.1} KiB vs serial {:>10.1} KiB \
             off-chip — {:>5.1}% reduction",
            a.app,
            a.mode,
            a.tiles,
            t.dataflow_offchip_bytes() as f64 / 1024.0,
            t.serial_offchip_bytes() as f64 / 1024.0,
            t.reduction() * 100.0
        );
        anyhow::ensure!(t.reduction() > 0.0, "{} must reduce off-chip traffic", a.app);
    }

    // The bf16 leg ran the identical tile stream: per-tile edge bytes
    // must come in at exactly half the f32 width.
    let edge = |t: &TrafficSnapshot| t.source_bytes + t.onchip_bytes + t.sink_bytes;
    let (f32_edge, bf16_edge) = (edge(&apps[0].traffic), edge(&apps[1].traffic));
    println!(
        "  bf16 edge bytes: {:.1} KiB vs f32 {:.1} KiB ({:.2}x)",
        bf16_edge as f64 / 1024.0,
        f32_edge as f64 / 1024.0,
        f32_edge as f64 / bf16_edge.max(1) as f64
    );
    anyhow::ensure!(
        bf16_edge * 2 == f32_edge,
        "bf16 tiles must cross edges at half width (bf16 {bf16_edge} vs f32 {f32_edge})"
    );

    // Harness overhead, after all traffic runs (arming the trace sink is
    // irreversible in-process).
    let (counters_tps, traced_tps, overhead) = telemetry_overhead(smoke)?;
    println!(
        "  telemetry overhead: counters {counters_tps:.0} tiles/s vs traced {traced_tps:.0} \
         tiles/s ({:+.2}%)",
        overhead * 100.0
    );

    // ---- BENCH_traffic.json -------------------------------------------
    let root = artifact_root();
    let mut json = String::from("{\n");
    let _ = writeln!(json, "  \"bench\": \"traffic_accounting\",");
    let _ = writeln!(json, "  \"smoke\": {smoke},");
    let _ = writeln!(json, "  \"apps\": [");
    for (i, a) in apps.iter().enumerate() {
        let comma = if i + 1 < apps.len() { "," } else { "" };
        let t = &a.traffic;
        let _ = writeln!(
            json,
            "    {{\"app\": \"{}\", \"mode\": \"{}\", \"tiles\": {}, \
             \"source_bytes\": {}, \"onchip_bytes\": {}, \"sink_bytes\": {}, \
             \"weight_bytes\": {}, \"dataflow_offchip_bytes\": {}, \
             \"serial_offchip_bytes\": {}, \"reduction\": {:.4}}}{comma}",
            a.app,
            a.mode,
            a.tiles,
            t.source_bytes,
            t.onchip_bytes,
            t.sink_bytes,
            t.weight_bytes,
            t.dataflow_offchip_bytes(),
            t.serial_offchip_bytes(),
            t.reduction()
        );
    }
    let _ = writeln!(json, "  ],");
    let _ = writeln!(
        json,
        "  \"telemetry_overhead\": {{\"counters_tiles_per_sec\": {counters_tps:.2}, \
         \"traced_tiles_per_sec\": {traced_tps:.2}, \"overhead_frac\": {overhead:.4}}}"
    );
    json.push_str("}\n");
    let out_path = root.join("BENCH_traffic.json");
    std::fs::write(&out_path, json)?;
    println!("traffic accounting written to {}", out_path.display());
    Ok(())
}
