//! Serving tier under offered load: closed-loop clients sweep the
//! `kitsune::serve` tier (continuous batching + EDF deadlines over one
//! warm pipeline) at increasing concurrency, recording completed
//! throughput, latency percentiles (p50/p95/p99) and shed rate at each
//! point, plus the saturation knee — the first client count where
//! completed throughput stops growing.
//!
//! Writes `BENCH_serve.json` at the repo root.
//! Run: `cargo bench --bench serve_load` (`BENCH_SMOKE=1` for CI).

use kitsune::bench::{artifact_root, smoke};
use kitsune::fault::FaultPlan;
use kitsune::serve::{BatchPolicy, ServeConfig, ServeError, Server};
use kitsune::session::{nerf_trunk_graph, Session};
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const TILES_PER_REQUEST: usize = 2;

struct Point {
    clients: usize,
    offered_rps: f64,
    completed_rps: f64,
    tiles_per_sec: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    shed_rate: f64,
}

/// Supervision-overhead probe: the same pipeline workload with (a) the
/// default empty fault plan (production hot path — one branch per tile)
/// and (b) an *armed but never-matching* plan, which pays the full spec
/// scan on every tile. Returns (clean tiles/s, armed tiles/s, overhead
/// fraction). The robustness contract is that (a) costs < 2% vs the
/// pre-supervision pipeline, for which (b) is the conservative bound —
/// it does strictly more work per tile than (a).
fn fault_overhead(smoke: bool) -> anyhow::Result<(f64, f64, f64)> {
    let build = |plan: Option<FaultPlan>| -> anyhow::Result<Session> {
        let mut b = Session::builder()
            .graph(nerf_trunk_graph(512, 60, 64, 3))
            .tile_rows(64)
            .workers(2);
        if let Some(p) = plan {
            b = b.fault_plan(p);
        }
        b.build()
    };
    let reps = if smoke { 4 } else { 16 };
    let measure = |session: &Session| -> anyhow::Result<f64> {
        session.run(session.make_tiles(4, 1)?)?; // prime the kernels
        let tiles = session.make_tiles(32, 2)?;
        let t0 = Instant::now();
        let mut n = 0u64;
        for _ in 0..reps {
            n += session.run(tiles.clone())?.outputs.len() as u64;
        }
        Ok(n as f64 / t0.elapsed().as_secs_f64().max(1e-12))
    };
    let clean = build(None)?;
    let clean_tps = measure(&clean)?;
    clean.shutdown();
    let armed = build(Some(FaultPlan::new().panic_at(usize::MAX, u64::MAX)))?;
    let armed_tps = measure(&armed)?;
    armed.shutdown();
    Ok((clean_tps, armed_tps, clean_tps / armed_tps.max(1e-12) - 1.0))
}

fn main() -> anyhow::Result<()> {
    let smoke = smoke();
    let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let counts: Vec<usize> = if smoke { vec![1, 2, 4] } else { vec![1, 2, 4, 8, 16] };
    let duration_s = if smoke { 0.25 } else { 1.0 };
    let deadline = Duration::from_millis(if smoke { 500 } else { 200 });
    println!(
        "serve load sweep (host parallelism: {host}, {}s/point, deadline {:?}):",
        duration_s, deadline
    );

    // One warm pipeline shared across points; a fresh server per point so
    // each point's counters and latency histogram start clean.
    let session = Arc::new(
        Session::builder()
            .graph(nerf_trunk_graph(512, 60, 64, 3))
            .tile_rows(64)
            .workers(2)
            .build()?,
    );
    session.run(session.make_tiles(4, 0xFACE)?)?; // prime the kernels

    let mut points: Vec<Point> = Vec::new();
    for &clients in &counts {
        let server = Server::single(
            "trunk",
            Arc::clone(&session),
            ServeConfig {
                batch: BatchPolicy { max_tiles: 16, max_delay: Duration::from_micros(500) },
                queue_depth: 64,
                default_deadline: None,
                max_retries: 1,
            },
        );
        let stop = AtomicBool::new(false);
        let t0 = Instant::now();
        let (attempted, completed, shed, tiles_done) =
            std::thread::scope(|scope| -> anyhow::Result<(u64, u64, u64, u64)> {
                let mut joins = Vec::new();
                for c in 0..clients {
                    let server = &server;
                    let session = &session;
                    let stop = &stop;
                    joins.push(scope.spawn(move || -> anyhow::Result<(u64, u64, u64, u64)> {
                        let template = session.make_tiles(TILES_PER_REQUEST, 0xA0 + c as u64)?;
                        let (mut att, mut comp, mut sh, mut tiles) = (0u64, 0u64, 0u64, 0u64);
                        while !stop.load(Ordering::Relaxed) {
                            att += 1;
                            match server.submit("trunk", template.clone(), Some(deadline)) {
                                Ok(h) => match h.wait() {
                                    Ok(r) => {
                                        comp += 1;
                                        tiles += r.outputs.len() as u64;
                                    }
                                    Err(
                                        ServeError::DeadlineExceeded { .. }
                                        | ServeError::ShuttingDown,
                                    ) => sh += 1,
                                    Err(e) => return Err(anyhow::anyhow!(e)),
                                },
                                Err(
                                    ServeError::DeadlineExceeded { .. }
                                    | ServeError::AdmissionRejected { .. },
                                ) => {
                                    sh += 1;
                                    // Shed: back off a beat before retrying.
                                    std::thread::sleep(Duration::from_micros(200));
                                }
                                Err(e) => return Err(anyhow::anyhow!(e)),
                            }
                        }
                        Ok((att, comp, sh, tiles))
                    }));
                }
                std::thread::sleep(Duration::from_secs_f64(duration_s));
                stop.store(true, Ordering::Relaxed);
                let mut totals = (0u64, 0u64, 0u64, 0u64);
                for j in joins {
                    let (a, c, s, t) = j.join().expect("client thread panicked")?;
                    totals.0 += a;
                    totals.1 += c;
                    totals.2 += s;
                    totals.3 += t;
                }
                Ok(totals)
            })?;
        let wall = t0.elapsed().as_secs_f64().max(1e-12);
        let stats = server.stats();
        server.shutdown();
        anyhow::ensure!(session.in_flight() == 0, "in-flight table must drain between points");
        let p = Point {
            clients,
            offered_rps: attempted as f64 / wall,
            completed_rps: completed as f64 / wall,
            tiles_per_sec: tiles_done as f64 / wall,
            p50_ms: stats.latency.p50_ms,
            p95_ms: stats.latency.p95_ms,
            p99_ms: stats.latency.p99_ms,
            shed_rate: shed as f64 / (attempted.max(1)) as f64,
        };
        println!(
            "  {clients:>3} clients: offered {:>8.1} req/s  completed {:>8.1} req/s  \
             ({:>8.1} tiles/s)  p50 {:>7.2} ms  p99 {:>7.2} ms  shed {:>5.1}%",
            p.offered_rps,
            p.completed_rps,
            p.tiles_per_sec,
            p.p50_ms,
            p.p99_ms,
            p.shed_rate * 100.0
        );
        points.push(p);
    }
    session.shutdown();

    // Saturation knee: the first point whose completed throughput gains
    // less than 10% over the previous one (0 = still scaling at the top
    // of the sweep).
    let mut knee_clients = 0usize;
    for w in points.windows(2) {
        if w[1].completed_rps < w[0].completed_rps * 1.10 {
            knee_clients = w[1].clients;
            break;
        }
    }
    if knee_clients == 0 {
        println!("  no saturation knee within the sweep (still scaling)");
    } else {
        println!("  saturation knee at {knee_clients} clients");
    }

    // Fault-injection harness overhead on the no-fault path.
    let (clean_tps, armed_tps, overhead) = fault_overhead(smoke)?;
    println!(
        "  fault harness overhead: clean {clean_tps:.0} tiles/s vs armed {armed_tps:.0} \
         tiles/s ({:+.2}%)",
        overhead * 100.0
    );

    // ---- BENCH_serve.json ---------------------------------------------
    let root = artifact_root();
    let mut json = String::from("{\n");
    let _ = writeln!(json, "  \"bench\": \"serve_load\",");
    let _ = writeln!(json, "  \"smoke\": {smoke},");
    let _ = writeln!(json, "  \"host_parallelism\": {host},");
    let _ = writeln!(json, "  \"duration_s\": {duration_s},");
    let _ = writeln!(json, "  \"tiles_per_request\": {TILES_PER_REQUEST},");
    let _ = writeln!(json, "  \"deadline_ms\": {},", deadline.as_millis());
    let _ = writeln!(json, "  \"points\": [");
    for (i, p) in points.iter().enumerate() {
        let comma = if i + 1 < points.len() { "," } else { "" };
        let _ = writeln!(
            json,
            "    {{\"clients\": {}, \"offered_rps\": {:.2}, \"completed_rps\": {:.2}, \
             \"tiles_per_sec\": {:.2}, \"p50_ms\": {:.3}, \"p95_ms\": {:.3}, \
             \"p99_ms\": {:.3}, \"shed_rate\": {:.4}}}{comma}",
            p.clients,
            p.offered_rps,
            p.completed_rps,
            p.tiles_per_sec,
            p.p50_ms,
            p.p95_ms,
            p.p99_ms,
            p.shed_rate
        );
    }
    let _ = writeln!(json, "  ],");
    let _ = writeln!(
        json,
        "  \"fault_overhead\": {{\"clean_tiles_per_sec\": {clean_tps:.2}, \
         \"armed_tiles_per_sec\": {armed_tps:.2}, \"overhead_frac\": {overhead:.4}}},"
    );
    let _ = writeln!(json, "  \"knee_clients\": {knee_clients}");
    json.push_str("}\n");
    let out_path = root.join("BENCH_serve.json");
    std::fs::write(&out_path, json)?;
    println!("serve load sweep written to {}", out_path.display());
    Ok(())
}
