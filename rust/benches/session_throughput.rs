//! Warm vs cold submit throughput through the `kitsune::session` façade
//! (tiles/sec) — the perf trajectory the persistent pipeline exists for:
//! a warm session amortizes compile/lower/spawn across the request
//! stream, a cold path pays it per batch.
//!
//! Run: `cargo bench --bench session_throughput`

use kitsune::session::{nerf_trunk_graph, Session};
use std::time::Instant;

const TILE_ROWS: usize = 64;
const TILES_PER_BATCH: usize = 32;
const BATCHES: usize = 6;

fn build() -> anyhow::Result<Session> {
    Session::builder()
        .graph(nerf_trunk_graph(2048, 60, 64, 3))
        .tile_rows(TILE_ROWS)
        .workers(2)
        .build()
}

fn main() -> anyhow::Result<()> {
    let total_tiles = (TILES_PER_BATCH * BATCHES) as f64;

    // Cold: build the whole session (compile + lower + spawn) per batch.
    let t0 = Instant::now();
    for b in 0..BATCHES {
        let session = build()?;
        let out = session.run(session.make_tiles(TILES_PER_BATCH, b as u64)?)?;
        assert_eq!(out.outputs.len(), TILES_PER_BATCH);
    }
    let cold_s = t0.elapsed().as_secs_f64();

    // Warm: one session, the same stream of batches.
    let session = build()?;
    let t0 = Instant::now();
    for b in 0..BATCHES {
        let out = session.run(session.make_tiles(TILES_PER_BATCH, b as u64)?)?;
        assert_eq!(out.outputs.len(), TILES_PER_BATCH);
    }
    let warm_s = t0.elapsed().as_secs_f64();

    println!("session submit throughput ({BATCHES} batches x {TILES_PER_BATCH} tiles, {TILE_ROWS} rows/tile):");
    println!(
        "  cold (build per batch): {:>8.1} ms  {:>8.1} tiles/s",
        cold_s * 1e3,
        total_tiles / cold_s.max(1e-12)
    );
    println!(
        "  warm (persistent pool): {:>8.1} ms  {:>8.1} tiles/s  ({:.2}x)",
        warm_s * 1e3,
        total_tiles / warm_s.max(1e-12),
        cold_s / warm_s.max(1e-12)
    );
    Ok(())
}
