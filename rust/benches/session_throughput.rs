//! Warm vs cold submit throughput through the `kitsune::session` façade
//! (tiles/sec) — the perf trajectory the persistent pipeline exists for:
//! a warm session amortizes compile/lower/spawn across the request
//! stream, a cold path pays it per batch.
//!
//! A third run drives the *same* warm pipeline with the pre-optimization
//! execution engine (scalar-reference kernels, per-instruction
//! allocation, tile/weights borrowed — exactly what the interpreter did
//! before the blocked/fused/in-place overhaul, with no extra copies
//! inflating the baseline), so the recorded `warm_over_reference` ratio
//! is the hot-path speedup measured on this machine, pipeline overheads
//! held equal. Two more warm legs bracket the storage/ISA axes: the
//! vector layer forced off (what `KITSUNE_SIMD=0` runs) and bf16 tile
//! storage (what `KITSUNE_PRECISION=bf16` runs).
//!
//! Writes `BENCH_interp.json` at the repo root, folding in the
//! `BENCH_interp.kernel.part` staged by `benches/kernel_throughput.rs`
//! when present (`make bench` runs both in that order).
//!
//! Run: `cargo bench --bench session_throughput` (`BENCH_SMOKE=1` for CI).

use kitsune::bench::{artifact_root, smoke};
use kitsune::compiler::{compile, SelectOptions};
use kitsune::runtime::interp::Program;
use kitsune::runtime::{simd, ArtifactStore, EntrySpec, Executable, Precision, Rng, Tensor};
use kitsune::session::{lower_app, nerf_trunk_graph, LowerOptions, PipelineService, Session};
use kitsune::sim::GpuConfig;
use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Instant;

const TILE_ROWS: usize = 64;
const ROWS: usize = 2048;
const IN_DIM: usize = 60;
const HIDDEN: usize = 64;
const OUT_DIM: usize = 3;

fn build() -> anyhow::Result<Session> {
    Session::builder()
        .graph(nerf_trunk_graph(ROWS, IN_DIM, HIDDEN, OUT_DIM))
        .tile_rows(TILE_ROWS)
        .workers(2)
        .build()
}

/// The pre-overhaul execution engine, reproduced exactly: scalar
/// reference kernels, a fresh allocation per instruction, tile and
/// weights borrowed just like the old `run_bound` did — the baseline
/// pays no copy the old engine didn't, so `warm_over_reference` is a
/// pure kernel-architecture comparison.
struct ReferenceExec {
    program: Program,
    bound: Vec<Tensor>,
}

impl Executable for ReferenceExec {
    fn run_f32(&self, inputs: &[Tensor]) -> kitsune::Result<Vec<Tensor>> {
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.program.run_reference_bound(&refs, &self.bound)
    }

    fn run_f32_ref(&self, inputs: &[&Tensor]) -> kitsune::Result<Vec<Tensor>> {
        self.program.run_reference_bound(inputs, &self.bound)
    }
}

fn make_tiles(n: usize, seed: u64, rows: usize, dim: usize) -> Vec<Tensor> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| Tensor {
            dims: vec![rows, dim],
            data: (0..rows * dim).map(|_| rng.normal()).collect(),
            prec: kitsune::runtime::Precision::F32,
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let smoke = smoke();
    let (tiles_per_batch, batches) = if smoke { (8usize, 2usize) } else { (32, 6) };
    let total_tiles = (tiles_per_batch * batches) as f64;

    // Cold: build the whole session (compile + lower + spawn) per batch.
    let t0 = Instant::now();
    for b in 0..batches {
        let session = build()?;
        let out = session.run(session.make_tiles(tiles_per_batch, b as u64)?)?;
        assert_eq!(out.outputs.len(), tiles_per_batch);
    }
    let cold_s = t0.elapsed().as_secs_f64();

    // Warm: one session, the same stream of batches (one unmeasured
    // priming batch so pool wake-up is off the clock — the reference
    // pipeline below gets the same treatment).
    let session = build()?;
    session.run(session.make_tiles(tiles_per_batch, 999)?)?;
    let t0 = Instant::now();
    for b in 0..batches {
        let out = session.run(session.make_tiles(tiles_per_batch, b as u64)?)?;
        assert_eq!(out.outputs.len(), tiles_per_batch);
    }
    let warm_s = t0.elapsed().as_secs_f64();
    session.shutdown();

    // Warm again with the vector layer forced off (`KITSUNE_SIMD=0`):
    // same engine, scalar kernels — isolates the SIMD dispatch win on
    // the full pipeline, overheads held equal.
    let prev = simd::vector_enabled();
    simd::set_vector_enabled(false);
    let session = build()?;
    session.run(session.make_tiles(tiles_per_batch, 999)?)?;
    let t0 = Instant::now();
    for b in 0..batches {
        let out = session.run(session.make_tiles(tiles_per_batch, b as u64)?)?;
        assert_eq!(out.outputs.len(), tiles_per_batch);
    }
    let scalar_s = t0.elapsed().as_secs_f64();
    session.shutdown();
    simd::set_vector_enabled(prev);

    // Warm bf16: the same trunk with 16-bit tile/weight storage (f32
    // accumulate inside the kernels) — the reduced-width leg.
    let session = Session::builder()
        .graph(nerf_trunk_graph(ROWS, IN_DIM, HIDDEN, OUT_DIM))
        .tile_rows(TILE_ROWS)
        .workers(2)
        .precision(Precision::Bf16)
        .build()?;
    session.run(session.make_tiles(tiles_per_batch, 999)?)?;
    let t0 = Instant::now();
    for b in 0..batches {
        let out = session.run(session.make_tiles(tiles_per_batch, b as u64)?)?;
        assert_eq!(out.outputs.len(), tiles_per_batch);
    }
    let bf16_s = t0.elapsed().as_secs_f64();
    session.shutdown();

    // Reference warm: identical pipeline topology and worker counts, but
    // every stage kernel runs the pre-overhaul engine.
    let g = nerf_trunk_graph(ROWS, IN_DIM, HIDDEN, OUT_DIM);
    let app = compile(&g, &GpuConfig::a100(), &SelectOptions::default())?;
    let low = lower_app(
        &g,
        &app,
        &LowerOptions { tile_rows: Some(TILE_ROWS), ..LowerOptions::default() },
    )?;
    let execs: Vec<(EntrySpec, Box<dyn Executable>)> = low
        .entries
        .iter()
        .map(|(spec, program, weights)| {
            let exe: Box<dyn Executable> = Box::new(ReferenceExec {
                program: program.clone(),
                bound: weights.clone(),
            });
            (spec.clone(), exe)
        })
        .collect();
    let store = Arc::new(ArtifactStore::from_executables("reference", execs));
    let svc = PipelineService::start(
        Arc::clone(&store),
        &low.pipeline,
        vec![low.tile_rows, low.in_dim],
        Arc::new(kitsune::fault::FaultPlan::new()),
    )?;
    svc.submit(make_tiles(tiles_per_batch, 999, low.tile_rows, low.in_dim))?.wait()?;
    let t0 = Instant::now();
    for b in 0..batches {
        let out = svc
            .submit(make_tiles(tiles_per_batch, b as u64, low.tile_rows, low.in_dim))?
            .wait()?;
        assert_eq!(out.outputs.len(), tiles_per_batch);
    }
    let ref_s = t0.elapsed().as_secs_f64();
    svc.shutdown();

    let cold_tps = total_tiles / cold_s.max(1e-12);
    let warm_tps = total_tiles / warm_s.max(1e-12);
    let scalar_tps = total_tiles / scalar_s.max(1e-12);
    let bf16_tps = total_tiles / bf16_s.max(1e-12);
    let ref_tps = total_tiles / ref_s.max(1e-12);

    println!(
        "session submit throughput ({batches} batches x {tiles_per_batch} tiles, {TILE_ROWS} rows/tile):"
    );
    println!("  cold (build per batch):     {:>8.1} ms  {cold_tps:>8.1} tiles/s", cold_s * 1e3);
    println!(
        "  warm (persistent pool):     {:>8.1} ms  {warm_tps:>8.1} tiles/s  ({:.2}x vs cold)",
        warm_s * 1e3,
        cold_s / warm_s.max(1e-12)
    );
    println!(
        "  warm, KITSUNE_SIMD=0:       {:>8.1} ms  {scalar_tps:>8.1} tiles/s  (simd [{}] is {:.2}x)",
        scalar_s * 1e3,
        simd::dispatch_label(),
        warm_tps / scalar_tps.max(1e-12)
    );
    println!(
        "  warm, bf16 storage:         {:>8.1} ms  {bf16_tps:>8.1} tiles/s  ({:.2}x vs f32)",
        bf16_s * 1e3,
        bf16_tps / warm_tps.max(1e-12)
    );
    println!(
        "  warm, pre-overhaul engine:  {:>8.1} ms  {ref_tps:>8.1} tiles/s  (optimized is {:.2}x)",
        ref_s * 1e3,
        warm_tps / ref_tps.max(1e-12)
    );

    // Assemble BENCH_interp.json (+ the kernel part, if staged).
    let root = artifact_root();
    let mut json = String::from("{\n");
    let _ = writeln!(json, "  \"bench\": \"interp_hot_path\",");
    let _ = writeln!(json, "  \"smoke\": {smoke},");
    let _ = writeln!(json, "  \"session\": {{");
    let _ = writeln!(json, "    \"tile_rows\": {TILE_ROWS},");
    let _ = writeln!(json, "    \"tiles_per_batch\": {tiles_per_batch},");
    let _ = writeln!(json, "    \"batches\": {batches},");
    let _ = writeln!(json, "    \"cold_tiles_per_sec\": {cold_tps:.2},");
    let _ = writeln!(json, "    \"warm_tiles_per_sec\": {warm_tps:.2},");
    let _ = writeln!(json, "    \"warm_over_cold\": {:.3},", warm_tps / cold_tps.max(1e-12));
    let _ = writeln!(json, "    \"simd_dispatch\": \"{}\",", simd::dispatch_label());
    let _ = writeln!(json, "    \"scalar_warm_tiles_per_sec\": {scalar_tps:.2},");
    let _ = writeln!(
        json,
        "    \"simd_speedup_warm\": {:.3},",
        warm_tps / scalar_tps.max(1e-12)
    );
    let _ = writeln!(json, "    \"bf16_warm_tiles_per_sec\": {bf16_tps:.2},");
    let _ = writeln!(
        json,
        "    \"bf16_over_f32_warm\": {:.3},",
        bf16_tps / warm_tps.max(1e-12)
    );
    let _ = writeln!(json, "    \"reference_warm_tiles_per_sec\": {ref_tps:.2},");
    let _ = writeln!(
        json,
        "    \"warm_over_reference\": {:.3}",
        warm_tps / ref_tps.max(1e-12)
    );
    let _ = writeln!(json, "  }},");
    let _ = writeln!(json, "  \"kernel\": {{");
    let part_path = root.join("BENCH_interp.kernel.part");
    let mut kernel_lines: Vec<(String, String)> = Vec::new();
    if let Ok(part) = std::fs::read_to_string(&part_path) {
        for line in part.lines() {
            if let Some((k, v)) = line.split_once(' ') {
                if !k.is_empty() && v.parse::<f64>().is_ok() {
                    kernel_lines.push((k.to_string(), v.to_string()));
                }
            }
        }
    }
    for (i, (k, v)) in kernel_lines.iter().enumerate() {
        let comma = if i + 1 < kernel_lines.len() { "," } else { "" };
        let _ = writeln!(json, "    \"{k}\": {v}{comma}");
    }
    let _ = writeln!(json, "  }}");
    json.push_str("}\n");
    let out_path = root.join("BENCH_interp.json");
    std::fs::write(&out_path, json)?;
    let _ = std::fs::remove_file(&part_path);
    println!("bench trajectory written to {}", out_path.display());
    Ok(())
}
