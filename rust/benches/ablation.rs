//! Bench: ablation of Kitsune's design choices (dual-arbiter scheduler,
//! queue depth, tile granularity, ILP load balancing) — the DESIGN.md §4
//! decisions, each knocked out independently.
use kitsune::bench::bench;
use kitsune::report::ablation_table;
use kitsune::sim::GpuConfig;

fn main() {
    let cfg = GpuConfig::a100();
    println!("{}", ablation_table(&cfg).unwrap());
    bench("ablation/full-matrix", 0, 3, || ablation_table(&cfg).unwrap());
}
