//! Bench: regenerate paper Table 2 (fusion coverage + traffic reduction)
//! and time the full compiler+simulator evaluation behind it.
use kitsune::apps;
use kitsune::bench::bench;
use kitsune::compiler::{compile, SelectOptions};
use kitsune::report;
use kitsune::sim::GpuConfig;

fn main() {
    let cfg = GpuConfig::a100();
    let inf = report::evaluate_suite(&apps::inference_suite(), &cfg).unwrap();
    let tr = report::evaluate_suite(&apps::training_suite(), &cfg).unwrap();
    println!("{}", report::table2(&inf, &tr));
    let nerf = apps::nerf::inference(&apps::nerf::NerfConfig::default());
    bench("table2/compile-nerf", 2, 50, || {
        compile(&nerf, &cfg, &SelectOptions::default()).unwrap()
    });
    bench("table2/full-inference-suite", 1, 5, || {
        report::evaluate_suite(&apps::inference_suite(), &cfg).unwrap()
    });
}
