//! Bench: regenerate paper Figs 3 and 13 (runtime in SM x DRAM
//! utilization quadrants, baseline vs Kitsune).
use kitsune::apps;
use kitsune::bench::bench;
use kitsune::report;
use kitsune::sim::GpuConfig;

fn main() {
    let cfg = GpuConfig::a100();
    let inf = report::evaluate_suite(&apps::inference_suite(), &cfg).unwrap();
    let tr = report::evaluate_suite(&apps::training_suite(), &cfg).unwrap();
    println!("{}", report::fig3(&inf, &tr));
    println!("{}", report::fig13(&inf, &tr));
    let (name, g) = &apps::inference_suite()[2]; // MGN
    bench("fig3+13/evaluate-mgn", 1, 5, || {
        report::evaluate_app(name, g, &cfg).unwrap()
    });
}
