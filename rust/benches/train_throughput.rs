//! Training throughput: warm DAG-pipeline optimizer steps/sec vs the
//! serial tiled baseline (`kitsune::train::serial_step` — the same stage
//! programs run back-to-back on one thread, the host analog of
//! bulk-synchronous training). This is the training counterpart of
//! `benches/session_throughput.rs` and the paper's Figs 12/14 axis:
//! dataflow execution of the *backward* graph.
//!
//! Writes `BENCH_train.json` at the repo root, alongside
//! `BENCH_interp.json`.
//!
//! Run: `cargo bench --bench train_throughput` (`BENCH_SMOKE=1` for CI).

use kitsune::apps::nerf;
use kitsune::bench::{artifact_root, smoke};
use kitsune::session::Session;
use kitsune::train::{serial_step, split_batch, OptimizerKind};
use std::fmt::Write as _;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let smoke = smoke();
    // Small enough for interpreter kernels, big enough that tiles queue up.
    let cfg = if smoke {
        nerf::NerfConfig { batch: 128, pos_enc: 8, dir_enc: 4, hidden: 16, depth: 3, skip_at: 1 }
    } else {
        nerf::NerfConfig { batch: 1024, pos_enc: 24, dir_enc: 8, hidden: 64, depth: 4, skip_at: 2 }
    };
    let tile_rows = cfg.batch / 16;
    let steps = if smoke { 3usize } else { 20 };

    let session = Session::builder()
        .graph(nerf::training(&cfg))
        .tile_rows(tile_rows)
        .build()?;
    let plan = session.train_plan().expect("NeRF training lowers to the DAG pipeline");
    let batch = session.make_train_batch(0xBE9C)?;
    let tiles = split_batch(plan, &batch)?;
    println!(
        "train pipeline: {} stages, {} edges ({} skip links, {} multicast ports), \
         {} tiles/step x {} rows",
        plan.pipeline.stages.len(),
        plan.pipeline.edges.len(),
        plan.n_skip_links(),
        plan.n_multicasts(),
        plan.n_tiles(),
        plan.tile_rows,
    );

    // Serial baseline over the same tiles and fixed initial parameters.
    let params0: Vec<_> = plan.params.iter().map(|p| p.init.clone()).collect();
    let t0 = Instant::now();
    let mut serial_loss = f32::NAN;
    for _ in 0..steps {
        serial_loss = serial_step(plan, &params0, &tiles)?.loss;
    }
    let serial_s = t0.elapsed().as_secs_f64();

    // Warm pipeline: same step count through the persistent DAG pool,
    // with real optimizer updates (one unmeasured priming step).
    let mut trainer = session.trainer_with(OptimizerKind::sgd(1e-2))?;
    let first = trainer.step(&batch)?;
    assert_eq!(
        first.loss.to_bits(),
        serial_loss.to_bits(),
        "pipeline and serial baseline must agree bitwise on the first step"
    );
    let t0 = Instant::now();
    let mut last_loss = first.loss;
    for _ in 0..steps {
        last_loss = trainer.step(&batch)?.loss;
    }
    let warm_s = t0.elapsed().as_secs_f64();
    session.shutdown();

    let serial_sps = steps as f64 / serial_s.max(1e-12);
    let warm_sps = steps as f64 / warm_s.max(1e-12);
    println!("  serial baseline:  {:>8.2} ms/step  {serial_sps:>7.2} steps/s", 1e3 * serial_s / steps as f64);
    println!(
        "  warm pipeline:    {:>8.2} ms/step  {warm_sps:>7.2} steps/s  ({:.2}x vs serial)",
        1e3 * warm_s / steps as f64,
        warm_sps / serial_sps.max(1e-12)
    );
    println!("  loss after {} steps: {:.6} (first {:.6})", steps + 1, last_loss, first.loss);

    let root = artifact_root();
    let mut json = String::from("{\n");
    let _ = writeln!(json, "  \"bench\": \"train_throughput\",");
    let _ = writeln!(json, "  \"smoke\": {smoke},");
    let _ = writeln!(json, "  \"train\": {{");
    let _ = writeln!(json, "    \"batch_rows\": {},", plan.batch_rows);
    let _ = writeln!(json, "    \"tile_rows\": {},", plan.tile_rows);
    let _ = writeln!(json, "    \"tiles_per_step\": {},", plan.n_tiles());
    let _ = writeln!(json, "    \"stages\": {},", plan.pipeline.stages.len());
    let _ = writeln!(json, "    \"skip_links\": {},", plan.n_skip_links());
    let _ = writeln!(json, "    \"multicast_ports\": {},", plan.n_multicasts());
    let _ = writeln!(json, "    \"steps\": {steps},");
    let _ = writeln!(json, "    \"serial_steps_per_sec\": {serial_sps:.3},");
    let _ = writeln!(json, "    \"warm_steps_per_sec\": {warm_sps:.3},");
    let _ = writeln!(
        json,
        "    \"warm_over_serial\": {:.3},",
        warm_sps / serial_sps.max(1e-12)
    );
    let _ = writeln!(json, "    \"first_loss\": {:.6},", first.loss);
    let _ = writeln!(json, "    \"last_loss\": {last_loss:.6}");
    let _ = writeln!(json, "  }}");
    json.push_str("}\n");
    let out_path = root.join("BENCH_train.json");
    std::fs::write(&out_path, json)?;
    println!("training throughput written to {}", out_path.display());
    Ok(())
}
