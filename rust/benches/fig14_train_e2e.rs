//! Bench: regenerate paper Fig 14 (training end-to-end speedups).
use kitsune::apps;
use kitsune::bench::bench;
use kitsune::report;
use kitsune::sim::GpuConfig;

fn main() {
    let cfg = GpuConfig::a100();
    let suite = apps::training_suite();
    let evals = report::evaluate_suite(&suite, &cfg).unwrap();
    println!(
        "{}",
        report::e2e_speedups("Fig 14. Training end-to-end speedup over bulk-sync.", &evals)
    );
    bench("fig14/full-training-suite", 1, 3, || {
        report::evaluate_suite(&suite, &cfg).unwrap()
    });
}
