//! Bench: regenerate paper Fig 12 (training subgraph speedups, fwd/bwd
//! split, incl. sensitivity).
use kitsune::apps;
use kitsune::bench::bench;
use kitsune::report;

fn main() {
    let cfgs = report::sensitivity_configs();
    let names: Vec<String> = cfgs.iter().map(|c| c.name.clone()).collect();
    let suite = apps::training_suite();
    let evals: Vec<_> = cfgs
        .iter()
        .map(|c| report::evaluate_suite(&suite, c).unwrap())
        .collect();
    println!(
        "{}",
        report::subgraph_speedups(
            "Fig 12. Training subgraph speedups over bulk-sync (with sensitivity).",
            &names,
            &evals,
            true
        )
    );
    let (name, g) = &suite[3]; // NERF training
    bench("fig12/evaluate-nerf-train", 1, 5, || {
        report::evaluate_app(name, g, &cfgs[0]).unwrap()
    });
}
