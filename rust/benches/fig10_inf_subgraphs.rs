//! Bench: regenerate paper Fig 10 (inference subgraph speedups incl.
//! hardware sensitivity) and time the per-app evaluation.
use kitsune::apps;
use kitsune::bench::bench;
use kitsune::report;

fn main() {
    let cfgs = report::sensitivity_configs();
    let names: Vec<String> = cfgs.iter().map(|c| c.name.clone()).collect();
    let suite = apps::inference_suite();
    let evals: Vec<_> = cfgs
        .iter()
        .map(|c| report::evaluate_suite(&suite, c).unwrap())
        .collect();
    println!(
        "{}",
        report::subgraph_speedups(
            "Fig 10. Inference subgraph speedups over bulk-sync (with sensitivity).",
            &names,
            &evals,
            false
        )
    );
    let (name, g) = &suite[3]; // NERF
    bench("fig10/evaluate-nerf", 1, 10, || {
        report::evaluate_app(name, g, &cfgs[0]).unwrap()
    });
}
