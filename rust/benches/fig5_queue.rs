//! Bench: regenerate paper Fig 5 (queue bandwidth vs payload, sync
//! on/off) and time the analytic model sweep.
use kitsune::bench::bench;
use kitsune::queue::QueueModel;
use kitsune::report;
use kitsune::sim::GpuConfig;

fn main() {
    let cfg = GpuConfig::a100();
    println!("{}", report::fig5(&cfg));
    let model = QueueModel::new(cfg);
    bench("fig5/sweep-54-queues", 3, 100, || model.fig5_sweep(54));
    bench("fig5/single-point", 3, 1000, || model.evaluate(128 * 1024, 54, true));
}
