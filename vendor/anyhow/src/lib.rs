//! Minimal, offline stand-in for the `anyhow` crate.
//!
//! The container this repo builds in has no crates.io access, so the main
//! crate depends on this shim under the dependency alias `anyhow`. It
//! implements exactly the surface the workspace uses:
//!
//! * [`Error`] — a context-carrying, downcastable error value;
//! * [`Result<T>`] with `E = Error` defaulted;
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros;
//! * the [`Context`] extension trait for `Result` and `Option`.
//!
//! Semantics follow the real crate where it matters: `Display` shows only
//! the outermost context, `Debug` shows the full cause chain, `?` converts
//! any `std::error::Error + Send + Sync + 'static`, and `downcast_ref`
//! reaches the original typed error through any number of context frames.

use std::error::Error as StdError;
use std::fmt;

/// `Result` with this crate's [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A wrapper error: an outermost message, a chain of earlier messages, and
/// (when constructed from a typed error) the original value for downcasts.
pub struct Error {
    msg: String,
    /// Earlier messages, outermost-first (grown by [`Error::context`]).
    chain: Vec<String>,
    /// The original typed error, kept for [`Error::downcast_ref`].
    root: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Error from a plain message (what [`anyhow!`] produces).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), chain: Vec::new(), root: None }
    }

    /// Error wrapping a typed error value.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Error { msg: error.to_string(), chain: Vec::new(), root: Some(Box::new(error)) }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        let mut chain = Vec::with_capacity(1 + self.chain.len());
        chain.push(self.msg);
        chain.extend(self.chain);
        Error { msg: context.to_string(), chain, root: self.root }
    }

    /// Reference to the original typed error, if this `Error` wraps one.
    pub fn downcast_ref<T: StdError + 'static>(&self) -> Option<&T> {
        self.root.as_deref().and_then(|e| e.downcast_ref::<T>())
    }

    /// Is the original typed error a `T`?
    pub fn is<T: StdError + 'static>(&self) -> bool {
        self.downcast_ref::<T>().is_some()
    }

    /// The innermost message of the cause chain.
    pub fn root_cause_message(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or(&self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if !self.chain.is_empty() {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

mod private {
    use super::{Error, StdError};

    /// Sealed helper so `Context` applies to `Result<T, E>` for both
    /// typed errors and [`Error`] itself — the same device the real
    /// crate uses (its private `ext::StdError`). Coherence of the two
    /// impls rests on `Error` not implementing `std::error::Error`.
    pub trait ErrorLike {
        fn into_error(self) -> Error;
    }

    impl<E: StdError + Send + Sync + 'static> ErrorLike for E {
        fn into_error(self) -> Error {
            Error::new(self)
        }
    }

    impl ErrorLike for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result<T, E>` (typed errors *and* `anyhow::Result`) and `Option<T>`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: private::ErrorLike> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// `if !cond { bail!(..) }`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io;

    fn fail_io() -> Result<()> {
        Err(io::Error::new(io::ErrorKind::NotFound, "gone"))?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_typed_errors() {
        let e = fail_io().unwrap_err();
        assert_eq!(e.to_string(), "gone");
        assert!(e.downcast_ref::<io::Error>().is_some());
    }

    #[test]
    fn context_stacks_and_display_shows_outermost() {
        let e = fail_io().unwrap_err().context("reading manifest").context("loading store");
        assert_eq!(e.to_string(), "loading store");
        assert_eq!(e.root_cause_message(), "gone");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("reading manifest"), "{dbg}");
        assert!(dbg.contains("gone"), "{dbg}");
        assert!(e.downcast_ref::<io::Error>().is_some(), "downcast through context");
    }

    #[test]
    fn context_on_anyhow_result() {
        // Context must also apply when the error already is an `Error`
        // (real-anyhow behavior the runtime's interp backend relies on).
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(e.root_cause_message(), "inner");
        let r: Result<()> = Err(anyhow!("inner2"));
        let e = r.with_context(|| format!("outer {}", 2)).unwrap_err();
        assert_eq!(e.to_string(), "outer 2");
    }

    #[test]
    fn context_trait_on_result_and_option() {
        let r: std::result::Result<(), io::Error> =
            Err(io::Error::new(io::ErrorKind::Other, "boom"));
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "step 3");
        let o: Option<u32> = None;
        let e = o.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        assert_eq!(Some(5u32).context("present").unwrap(), 5);
    }

    #[test]
    fn macros_format() {
        let name = "entry";
        let e = anyhow!("unknown artifact entry {name}");
        assert_eq!(e.to_string(), "unknown artifact entry entry");
        let e = anyhow!("{} of {}", 2, 3);
        assert_eq!(e.to_string(), "2 of 3");
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "too big: {x}");
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero");
        assert_eq!(f(12).unwrap_err().to_string(), "too big: 12");
    }
}
