//! Type-level stub of the `xla` crate's PJRT surface.
//!
//! The main crate's `pjrt` runtime backend is written against the real
//! `xla` crate (PJRT C API bindings, xla-rs lineage). That crate cannot be
//! fetched in this offline environment, so this stub declares the exact
//! API surface `runtime::pjrt` consumes — enough for
//! `cargo check --features pjrt` to type-check the backend — while every
//! runtime entry point returns a clear "PJRT unavailable" error.
//!
//! To execute real HLO artifacts, point the `xla` dependency alias in
//! `rust/Cargo.toml` at the real crate instead of this stub (see
//! README.md); no source change in `runtime::pjrt` is needed.

use std::fmt;

/// Error type mirroring `xla::Error` as used by the runtime (`Display`).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!(
        "{what}: PJRT is unavailable in this build (offline xla stub); \
         swap the `xla` dependency alias to the real xla crate to execute \
         HLO artifacts, or use the default pure-Rust interpreter backend"
    )))
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Mirrors `execute<L: BorrowStoredLiteral>` from the real crate; the
    /// type parameter exists so turbofish call sites type-check.
    pub fn execute<L>(&self, _literals: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Parsed HLO module proto (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Element type tag (only the variant the runtime uses).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
}

/// Dense array shape (stub).
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable("Literal::to_tuple")
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        unavailable("Literal::array_shape")
    }

    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal, Error> {
        unavailable("Literal::convert")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_not_silently() {
        let err = PjRtClient::cpu().err().expect("stub client must not construct");
        assert!(err.to_string().contains("PJRT is unavailable"), "{err}");
    }
}
