# Convenience targets. `artifacts` is OPTIONAL: the Rust stack builds,
# tests and serves without it (pure-Rust interpreter backend); it is only
# needed to exercise the PJRT path against real AOT-lowered HLO.

.PHONY: all test artifacts bench bench-paper clean

all: test

test:
	cargo build --release && cargo test -q

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

# Interpreter hot-path trajectory: kernel GFLOP/s first (stages a part
# file, incl. the simd_speedup vector-vs-scalar micro-kernel leg), then
# session warm/cold/scalar/bf16/reference throughput, which folds both
# into BENCH_interp.json at the repo root; then training steps/sec
# (warm DAG pipeline vs serial baseline) into BENCH_train.json; then
# scheduler scaling (GEMM + warm pipeline + DAG training at 1/2/4/N
# workers) into BENCH_sched.json; then the serving-tier load sweep
# (latency percentiles vs offered load, saturation knee, shed rate)
# into BENCH_serve.json; then dataflow-vs-serial-oracle off-chip traffic
# accounting per app (+ the half-width bf16 inference leg and telemetry
# harness overhead) into BENCH_traffic.json.
# BENCH_SMOKE=1 for a fast CI smoke run that still emits the JSONs.
bench:
	cargo bench --bench kernel_throughput
	cargo bench --bench session_throughput
	cargo bench --bench train_throughput
	cargo bench --bench sched_scaling
	cargo bench --bench serve_load
	cargo bench --bench traffic_accounting

# The full paper-figure bench suite (fig*/table*/ablation/...).
bench-paper:
	cargo bench

clean:
	rm -rf target artifacts
