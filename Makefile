# Convenience targets. `artifacts` is OPTIONAL: the Rust stack builds,
# tests and serves without it (pure-Rust interpreter backend); it is only
# needed to exercise the PJRT path against real AOT-lowered HLO.

.PHONY: all test artifacts bench clean

all: test

test:
	cargo build --release && cargo test -q

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

bench:
	cargo bench

clean:
	rm -rf target artifacts
