"""Make the `compile` package importable regardless of pytest's CWD
(supports both `cd python && pytest tests/` and `pytest python/tests/`)."""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
