"""AOT contract tests: every entry lowers to HLO text the xla-crate side
can parse (no 64-bit-id serialized protos), and the manifest describes
the ABI accurately."""

import os

import jax
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def lowered_entries():
    return [(name, fn, args) for name, fn, args in aot.entries()]


def test_entry_names_unique(lowered_entries):
    names = [n for n, _, _ in lowered_entries]
    assert len(names) == len(set(names))


def test_every_entry_lowers_to_hlo_text(lowered_entries):
    for name, fn, args in lowered_entries:
        text = aot.to_hlo_text(jax.jit(fn).lower(*args))
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        # Interpret-mode pallas must not leak Mosaic custom-calls the CPU
        # PJRT client cannot execute.
        assert "tpu_custom_call" not in text, name


def test_manifest_roundtrip(tmp_path):
    """Running the emitter produces parseable manifest lines with the
    declared input arity."""
    import subprocess
    import sys

    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    lines = (out / "manifest.txt").read_text().strip().splitlines()
    assert len(lines) == len(aot.entries())
    for line, (name, _, args) in zip(lines, aot.entries()):
        fields = line.split("\t")
        assert fields[0] == name
        assert (out / fields[1]).exists()
        ins = fields[2][len("in=") :].split(",f32")  # crude arity check
        assert fields[2].count("[") == len(args)
        assert fields[3].startswith("out=")
        assert int(fields[3][4:]) >= 1
        del ins


def test_train_step_abi():
    """train_step: (x, y, *params) -> (loss, *new_params)."""
    _, fn, args = next(e for e in aot.entries() if e[0] == "train_step")
    out = jax.eval_shape(fn, *args)
    assert len(out) == 1 + len(model.PARAM_SHAPES)
    assert out[0].shape == ()  # scalar loss
    for o, s in zip(out[1:], model.PARAM_SHAPES):
        assert o.shape == tuple(s)
