"""L2 correctness: model shapes, pallas-vs-ref forward equivalence,
train-step descent, and pipeline-stage composition == monolithic forward."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model

KEY = jax.random.PRNGKey(0)


def data(batch=256):
    kx, ky = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(kx, (batch, model.IN_DIM), jnp.float32)
    y = jax.nn.sigmoid(jax.random.normal(ky, (batch, model.OUT_DIM), jnp.float32))
    return x, y


def test_param_shapes():
    params = model.init_params(KEY)
    assert [p.shape for p in params] == [tuple(s) for s in model.PARAM_SHAPES]


def test_forward_shape_and_range():
    params = model.init_params(KEY)
    x, _ = data()
    y = model.forward(x, *params)
    assert y.shape == (256, model.OUT_DIM)
    assert np.all(np.asarray(y) >= 0.0) and np.all(np.asarray(y) <= 1.0)


def test_pallas_forward_matches_ref():
    """The L1-kernel-backed forward must equal the pure-jnp forward —
    the whole-model analog of the kernel-vs-ref tests."""
    params = model.init_params(KEY)
    x, _ = data(512)
    y_ref = model.forward(x, *params, use_pallas=False)
    y_pal = model.forward(x, *params, use_pallas=True)
    np.testing.assert_allclose(
        np.asarray(y_pal), np.asarray(y_ref), rtol=2e-5, atol=2e-5
    )


def test_train_step_descends():
    params = model.init_params(KEY)
    x, y = data(512)
    losses = []
    for _ in range(30):
        loss, *params = model.train_step(x, y, *params)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]


def test_stage_composition_equals_forward():
    """Streaming the three pipeline stages over row tiles must reproduce
    the monolithic forward exactly — the property the Rust coordinator
    relies on."""
    params = model.init_params(KEY)
    w1, b1, w2, b2, w3, b3, w4, b4 = params
    x, _ = data(512)
    want = model.forward(x, *params)
    tile = 128
    outs = []
    for i in range(0, x.shape[0], tile):
        t = x[i : i + tile]
        h0 = model.stage_trunk0(t, w1, b1, w2, b2)
        h1 = model.stage_trunk1(h0, w3, b3)
        outs.append(model.stage_head(h1, w4, b4))
    got = jnp.concatenate(outs, axis=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)
