"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes and dtypes; fixed-seed numpy generates data.
This is the core correctness signal for the kernels the AOT artifacts
embed.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import elementwise, fused_mlp, ref, splitk_reduce

RNG = np.random.default_rng(0)


def randn(*shape, dtype=np.float32):
    return jnp.asarray(RNG.standard_normal(shape).astype(dtype))


TOL = dict(rtol=2e-5, atol=2e-5)
TOL_BF16 = dict(rtol=2e-2, atol=2e-2)


class TestFusedMlp:
    @settings(max_examples=25, deadline=None)
    @given(
        tiles=st.integers(1, 4),
        k=st.sampled_from([8, 60, 64]),
        h=st.sampled_from([32, 128, 256]),
        n=st.sampled_from([3, 16, 64]),
        tile_m=st.sampled_from([32, 64, 128]),
    )
    def test_matches_ref_f32(self, tiles, k, h, n, tile_m):
        m = tiles * tile_m
        x, w1, b1 = randn(m, k), randn(k, h), randn(h)
        w2, b2 = randn(h, n), randn(n)
        got = fused_mlp.fused_mlp(x, w1, b1, w2, b2, tile_m=tile_m)
        want = ref.fused_mlp(x, w1, b1, w2, b2)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)

    def test_bf16_inputs(self):
        m, k, h, n = 128, 60, 256, 3
        x = randn(m, k).astype(jnp.bfloat16)
        w1, b1 = randn(k, h).astype(jnp.bfloat16), randn(h).astype(jnp.bfloat16)
        w2, b2 = randn(h, n).astype(jnp.bfloat16), randn(n).astype(jnp.bfloat16)
        got = fused_mlp.fused_mlp(x, w1, b1, w2, b2)
        want = ref.fused_mlp(
            x.astype(jnp.float32),
            w1.astype(jnp.float32),
            b1.astype(jnp.float32),
            w2.astype(jnp.float32),
            b2.astype(jnp.float32),
        )
        np.testing.assert_allclose(
            np.asarray(got, dtype=np.float32), np.asarray(want), **TOL_BF16
        )

    def test_rejects_ragged_m(self):
        with pytest.raises(AssertionError):
            fused_mlp.fused_mlp(
                randn(100, 8), randn(8, 16), randn(16), randn(16, 4), randn(4),
                tile_m=64,
            )

    def test_relu_actually_clamps(self):
        # All-negative first-layer output => second GEMM sees zeros.
        x = jnp.ones((128, 8))
        w1 = -jnp.ones((8, 16))
        b1 = jnp.zeros(16)
        w2, b2 = randn(16, 4), randn(4)
        got = fused_mlp.fused_mlp(x, w1, b1, w2, b2)
        np.testing.assert_allclose(
            np.asarray(got), np.tile(np.asarray(b2), (128, 1)), **TOL
        )


class TestSplitK:
    @settings(max_examples=25, deadline=None)
    @given(
        m=st.sampled_from([16, 64, 128]),
        k=st.sampled_from([32, 64, 256]),
        n=st.sampled_from([8, 64, 128]),
        n_splits=st.sampled_from([1, 2, 4, 8]),
    )
    def test_matches_ref(self, m, k, n, n_splits):
        if k % n_splits:
            n_splits = 1
        x, w = randn(m, k), randn(k, n)
        got = splitk_reduce.splitk_matmul(x, w, n_splits=n_splits)
        want = ref.splitk_matmul(x, w, n_splits)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)

    def test_split_count_invariant(self):
        # Fig 2(b): the reduction tree's width must not change the result.
        x, w = randn(64, 256), randn(256, 32)
        base = splitk_reduce.splitk_matmul(x, w, n_splits=1)
        for s in (2, 4, 8, 16):
            got = splitk_reduce.splitk_matmul(x, w, n_splits=s)
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(base), rtol=1e-4, atol=1e-4
            )


class TestBatchReduce:
    @settings(max_examples=20, deadline=None)
    @given(
        m=st.sampled_from([8, 64, 512]),
        n=st.sampled_from([16, 256]),
        n_splits=st.sampled_from([1, 2, 4, 8]),
    )
    def test_matches_ref(self, m, n, n_splits):
        if m % n_splits:
            n_splits = 1
        x = randn(m, n)
        got = splitk_reduce.batch_reduce(x, n_splits=n_splits)
        want = ref.batch_reduce(x)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
        )


class TestBiasAct:
    @settings(max_examples=20, deadline=None)
    @given(
        tiles=st.integers(1, 3),
        n=st.sampled_from([16, 64, 256]),
        kind=st.sampled_from(["relu", "gelu", "sigmoid"]),
    )
    def test_matches_ref(self, tiles, n, kind):
        m = tiles * 64
        x, b = randn(m, n), randn(n)
        got = elementwise.bias_act(x, b, kind=kind, tile_m=64)
        want = ref.bias_act(x, b, kind=kind)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
        )

    def test_unknown_kind_raises(self):
        with pytest.raises(ValueError):
            elementwise.bias_act(randn(64, 8), randn(8), kind="tanhh")
