"""L1 Pallas kernel: fused elementwise epilogue stage (bias + activation).

The SIMT-class pipeline stage of the paper's spatial pipelines: consumes a
tile from the producer GEMM and applies bias + nonlinearity before pushing
downstream. Streams row tiles through VMEM.
"""

import functools

import jax
import jax.nn
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_M = 128


def _kernel(x_ref, b_ref, o_ref, *, kind):
    y = x_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)
    if kind == "relu":
        y = jnp.maximum(y, 0.0)
    elif kind == "gelu":
        y = jax.nn.gelu(y, approximate=True)
    elif kind == "sigmoid":
        y = jax.nn.sigmoid(y)
    else:
        raise ValueError(f"unknown activation {kind}")
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("kind", "tile_m"))
def bias_act(x, b, kind="relu", tile_m=DEFAULT_TILE_M):
    """``act(x + b)`` streamed over row tiles. x: [M, N], b: [N]."""
    m, n = x.shape
    tile_m = min(tile_m, m)
    assert m % tile_m == 0, f"M={m} not a multiple of tile_m={tile_m}"
    return pl.pallas_call(
        functools.partial(_kernel, kind=kind),
        grid=(m // tile_m,),
        in_specs=[
            pl.BlockSpec((tile_m, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_m, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, b)
