"""L1 Pallas kernel: tile-pipelined fused MLP (Linear -> ReLU -> Linear).

This is Kitsune's Fig 2(a) insight re-thought for TPU (DESIGN.md
§Hardware-Adaptation): where the GPU version streams the hidden-dimension
tile between producer/consumer CTAs through an L2-resident queue, the TPU
version keeps the ``(TILE_M, H)`` hidden tile in **VMEM scratch** between
the two MXU matmuls — the same "never let the intermediate touch HBM"
schedule, expressed with a BlockSpec grid over row tiles instead of CTAs.

VMEM budget per grid step (bf16/f32 mixed, f32 shown):
    x tile   TILE_M x K
    w1       K x H          (resident across steps)
    w2       H x N          (resident across steps)
    hidden   TILE_M x H     (scratch — the tile the GPU would queue)
    out      TILE_M x N
For the default NeRF-class shapes (K=60, H=256, N=256, TILE_M=128) this is
~0.4 MB — far under the ~16 MB VMEM of a TPU core, leaving room for the
double buffering the pipeline emitter adds.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; numerics are validated through the interpreter and the HLO
the surrounding jit emits is what the Rust runtime loads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_M = 128


def _kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref, *, acc_dtype):
    """One row-tile step: both GEMMs back to back, hidden stays in VMEM."""
    x = x_ref[...].astype(acc_dtype)
    w1 = w1_ref[...].astype(acc_dtype)
    # First GEMM + bias + ReLU. `h` lives in registers/VMEM only.
    h = jnp.dot(x, w1) + b1_ref[...].astype(acc_dtype)
    h = jnp.maximum(h, 0.0)
    # Second GEMM + bias.
    w2 = w2_ref[...].astype(acc_dtype)
    o = jnp.dot(h, w2) + b2_ref[...].astype(acc_dtype)
    o_ref[...] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_m",))
def fused_mlp(x, w1, b1, w2, b2, tile_m=DEFAULT_TILE_M):
    """``relu(x @ w1 + b1) @ w2 + b2`` without materializing the hidden.

    Args:
        x:  ``[M, K]`` activations (M must be a multiple of ``tile_m``,
            callers pad; the AOT entry points use fixed shapes anyway).
        w1: ``[K, H]``; b1: ``[H]``; w2: ``[H, N]``; b2: ``[N]``.
    """
    m, _ = x.shape
    k, h = w1.shape
    _, n = w2.shape
    tile_m = min(tile_m, m)
    assert m % tile_m == 0, f"M={m} not a multiple of tile_m={tile_m}"
    grid = (m // tile_m,)
    return pl.pallas_call(
        functools.partial(_kernel, acc_dtype=jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, k), lambda i: (i, 0)),  # stream row tiles
            pl.BlockSpec((k, h), lambda i: (0, 0)),  # weights resident
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h, n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_m, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(x, w1, b1, w2, b2)
