"""L1 Pallas kernel: split-K GEMM with a parallel partial-sum reduction.

The paper's Fig 2(b): reductions (split-K GEMMs, batch-dimension gradient
sums) starve for parallelism under BSP. Kitsune splits the reduction
dimension across CTAs and funnels partials through queues. On TPU the
same insight maps to a grid over K-slabs with an accumulating output
block: slab ``j`` computes ``x[:, j] @ w[j, :]`` on the MXU and adds it
into the VMEM-resident output tile — a many-to-one dataflow expressed by
the grid schedule instead of a queue.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref):
    """Grid step j: accumulate one K-slab's partial product."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    part = jnp.dot(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32)
    )
    o_ref[...] = o_ref[...] + part.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_splits",))
def splitk_matmul(x, w, n_splits=4):
    """``x[M,K] @ w[K,N]`` with K partitioned into ``n_splits`` slabs."""
    m, k = x.shape
    _, n = w.shape
    n_splits = min(n_splits, k)
    assert k % n_splits == 0, f"K={k} not a multiple of n_splits={n_splits}"
    slab = k // n_splits
    return pl.pallas_call(
        _kernel,
        grid=(n_splits,),
        in_specs=[
            pl.BlockSpec((m, slab), lambda j: (0, j)),
            pl.BlockSpec((slab, n), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((m, n), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w)


def _reduce_kernel(x_ref, o_ref):
    """Accumulate one batch slab into the running sum."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] = o_ref[...] + jnp.sum(
        x_ref[...].astype(jnp.float32), axis=0
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_splits",))
def batch_reduce(x, n_splits=8):
    """Gradient-style ``sum(x, axis=0)`` as a parallel fan-in tree."""
    m, n = x.shape
    n_splits = min(n_splits, m)
    assert m % n_splits == 0, f"M={m} not a multiple of n_splits={n_splits}"
    slab = m // n_splits
    return pl.pallas_call(
        _reduce_kernel,
        grid=(n_splits,),
        in_specs=[pl.BlockSpec((slab, n), lambda j: (j, 0))],
        out_specs=pl.BlockSpec((n,), lambda j: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(x)
