"""Pure-jnp oracles for the Pallas kernels (the correctness signal).

Every Layer-1 kernel in this package has an exact reference here; pytest
asserts allclose between the two across a hypothesis-driven shape/dtype
sweep. The references are also what the kernels lower *against* in the
L2 model when ``use_pallas=False``.
"""

import jax.nn
import jax.numpy as jnp


def fused_mlp(x, w1, b1, w2, b2):
    """Linear -> ReLU -> Linear with the hidden tile kept on chip.

    The paper's Fig 2(a) pattern: ``x[M,K] @ w1[K,H] + b1`` -> relu ->
    ``@ w2[H,N] + b2``. The Pallas kernel streams row tiles and never
    materializes the ``[M,H]`` intermediate in HBM.
    """
    h = jnp.maximum(x @ w1 + b1, 0.0)
    return h @ w2 + b2


def splitk_matmul(x, w, n_splits):
    """Split-K GEMM with explicit partial-sum reduction (Fig 2(b)).

    Functionally identical to ``x @ w``; the kernel partitions the K
    dimension into ``n_splits`` slabs reduced through a tree — the
    parallelism Kitsune extracts from reduction dimensions.
    """
    del n_splits  # shape-only parameter of the kernel
    return x @ w


def bias_act(x, b, kind="relu"):
    """Elementwise epilogue stage: bias add + activation."""
    y = x + b
    if kind == "relu":
        return jnp.maximum(y, 0.0)
    if kind == "gelu":
        return jax.nn.gelu(y, approximate=True)
    if kind == "sigmoid":
        return jax.nn.sigmoid(y)
    raise ValueError(f"unknown activation {kind}")


def batch_reduce(x):
    """Gradient-style reduction over the batch (leading) dimension."""
    return jnp.sum(x, axis=0)
