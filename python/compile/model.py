"""L2: the JAX model — a NeRF-class MLP (the paper's hidden-dim-256
challenge network), its loss, and an SGD train step.

Build-time only: ``aot.py`` lowers the jitted entry points to HLO text
that the Rust runtime executes through PJRT. Nothing here runs on the
request path.

Two forward paths:
* ``forward(..., use_pallas=True)`` routes the trunk's Linear->ReLU->Linear
  pairs through the L1 ``fused_mlp`` Pallas kernel (VMEM-resident hidden
  tile — the Kitsune schedule);
* ``use_pallas=False`` is the pure-jnp reference, used by ``jax.grad`` in
  the train step and as the pytest oracle.
"""

import jax
import jax.numpy as jnp

from .kernels import elementwise, fused_mlp, ref

# NeRF-class configuration (scaled for CPU-PJRT e2e training).
IN_DIM = 60  # positional encoding width
HIDDEN = 256
OUT_DIM = 3
LR = 1e-2

# Parameter list layout (flat, deterministic — the AOT ABI):
#   w1[IN,H] b1[H] w2[H,H] b2[H] w3[H,H] b3[H] w4[H,OUT] b4[OUT]
PARAM_SHAPES = [
    (IN_DIM, HIDDEN),
    (HIDDEN,),
    (HIDDEN, HIDDEN),
    (HIDDEN,),
    (HIDDEN, HIDDEN),
    (HIDDEN,),
    (HIDDEN, OUT_DIM),
    (OUT_DIM,),
]


def init_params(key):
    """He-initialized flat parameter list."""
    params = []
    for shape in PARAM_SHAPES:
        key, sub = jax.random.split(key)
        if len(shape) == 2:
            fan_in = shape[0]
            params.append(
                jax.random.normal(sub, shape, jnp.float32)
                * jnp.sqrt(2.0 / fan_in)
            )
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return params


def forward(x, *params, use_pallas=False):
    """MLP forward: trunk of three hidden layers + linear head + sigmoid."""
    w1, b1, w2, b2, w3, b3, w4, b4 = params
    if use_pallas:
        # Trunk pairs through the L1 kernel: hidden tiles stay in VMEM.
        h = fused_mlp.fused_mlp(x, w1, b1, w2, b2)
        h = jnp.maximum(h, 0.0)
        h = jnp.maximum(h @ w3 + b3, 0.0)
        y = elementwise.bias_act(h @ w4, b4, kind="sigmoid")
    else:
        h = jnp.maximum(ref.fused_mlp(x, w1, b1, w2, b2), 0.0)
        h = jnp.maximum(h @ w3 + b3, 0.0)
        y = ref.bias_act(h @ w4, b4, kind="sigmoid")
    return y


def loss_fn(params, x, y):
    """Photometric MSE (NeRF's training loss)."""
    pred = forward(x, *params, use_pallas=False)
    return jnp.mean((pred - y) ** 2)


def train_step(x, y, *params):
    """One SGD step. AOT ABI: ``(x, y, *params) -> (loss, *new_params)``."""
    loss, grads = jax.value_and_grad(loss_fn)(list(params), x, y)
    new_params = [p - LR * g for p, g in zip(params, grads)]
    return (loss, *new_params)


# --- Spatial-pipeline stage functions (the coordinator's stage kernels) ---
# The Rust coordinator streams row tiles through ring queues between these
# three stages — a host-level realization of the paper's execution model,
# each stage a separately compiled XLA executable.


def stage_trunk0(x, w1, b1, w2, b2):
    """Pipeline stage 0: the fused-MLP producer (TensorCore-class)."""
    return jnp.maximum(ref.fused_mlp(x, w1, b1, w2, b2), 0.0)


def stage_trunk1(h, w3, b3):
    """Pipeline stage 1: mid trunk layer."""
    return jnp.maximum(h @ w3 + b3, 0.0)


def stage_head(h, w4, b4):
    """Pipeline stage 2: color head + sigmoid (SIMT-class epilogue)."""
    return ref.bias_act(h @ w4, b4, kind="sigmoid")
