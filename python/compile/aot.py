"""AOT lowering: jit → StableHLO → XlaComputation → **HLO text**.

HLO *text* (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Emits one ``artifacts/<entry>.hlo.txt`` per entry point plus
``artifacts/manifest.txt`` describing the ABI, one line per entry:

    name<TAB>file<TAB>in=f32[1024,60],f32[60,256],...<TAB>out=<count>

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Fixed AOT shapes (PJRT executables are shape-specialized).
TRAIN_BATCH = 1024
FWD_BATCH = 1024
TILE_ROWS = 128  # coordinator streaming tile


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*dims, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(dims), dtype)


def param_specs():
    return [spec(*s) for s in model.PARAM_SHAPES]


def entries():
    """(name, fn, example_args) for every AOT entry point."""
    p = param_specs()
    h = model.HIDDEN
    return [
        # Full forward pass (quickstart / nerf_inference examples).
        (
            "nerf_forward",
            lambda x, *params: model.forward(x, *params, use_pallas=False),
            [spec(FWD_BATCH, model.IN_DIM), *p],
        ),
        # Forward with the L1 Pallas kernel inlined (interpret mode lowers
        # to plain HLO, so the Rust CPU client can run it — numerics must
        # match nerf_forward exactly; pytest enforces this).
        (
            "nerf_forward_pallas",
            lambda x, *params: model.forward(x, *params, use_pallas=True),
            [spec(FWD_BATCH, model.IN_DIM), *p],
        ),
        # One SGD training step (e2e_train example).
        (
            "train_step",
            model.train_step,
            [spec(TRAIN_BATCH, model.IN_DIM), spec(TRAIN_BATCH, model.OUT_DIM), *p],
        ),
        # Spatial-pipeline stages (llama_serving/coordinator demo): tile in,
        # tile out, weights as trailing args.
        (
            "stage_trunk0",
            model.stage_trunk0,
            [spec(TILE_ROWS, model.IN_DIM), p[0], p[1], p[2], p[3]],
        ),
        (
            "stage_trunk1",
            model.stage_trunk1,
            [spec(TILE_ROWS, h), p[4], p[5]],
        ),
        (
            "stage_head",
            model.stage_head,
            [spec(TILE_ROWS, h), p[6], p[7]],
        ),
    ]


def fmt_spec(s) -> str:
    dt = jnp.dtype(s.dtype).name
    return f"{dt}[{','.join(str(d) for d in s.shape)}]"


def n_outputs(fn, example_args) -> int:
    out = jax.eval_shape(fn, *example_args)
    if isinstance(out, (tuple, list)):
        return len(out)
    return 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for name, fn, example_args in entries():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        ins = ",".join(fmt_spec(s) for s in example_args)
        outs = n_outputs(fn, example_args)
        manifest.append(f"{name}\t{name}.hlo.txt\tin={ins}\tout={outs}")
        print(f"  {name}: {len(text)} chars, {len(example_args)} inputs, {outs} outputs")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
