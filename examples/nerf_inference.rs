//! NeRF inference — the paper's showcase application (§6.3): all forward
//! ops spatially fused, concats on SIMT pipes while GEMMs use the
//! TensorCores, 2.3x subgraph speedup and ~98% traffic reduction.
//!
//! Runs through the `kitsune::session` façade: one build compiles the
//! suite graph, `simulate()` produces the per-sf-node breakdown the
//! paper's Fig 10 plots. Then (if `make artifacts` has run) executes the
//! *real* NeRF trunk through the runtime to confirm the numerics the
//! simulator is reasoning about.
//!
//! Run: `cargo run --release --example nerf_inference`

use kitsune::runtime::{ArtifactStore, Rng, Tensor};
use kitsune::session::Session;

fn main() -> anyhow::Result<()> {
    let session = Session::builder().app("NERF").build()?;
    let eval = session.simulate()?;

    println!("NeRF inference on simulated {}:", session.config().name);
    println!(
        "  bulk-sync  {:>8.1} us   DRAM {:>7.1} MB",
        eval.bsp.sim.elapsed_s * 1e6,
        eval.bsp.sim.dram_bytes / 1e6
    );
    println!(
        "  vertical   {:>8.1} us   DRAM {:>7.1} MB   ({:.2}x)",
        eval.vertical.sim.elapsed_s * 1e6,
        eval.vertical.sim.dram_bytes / 1e6,
        eval.vertical_speedup()
    );
    println!(
        "  kitsune    {:>8.1} us   DRAM {:>7.1} MB   ({:.2}x, traffic -{:.1}%)",
        eval.kitsune.sim.elapsed_s * 1e6,
        eval.kitsune.sim.dram_bytes / 1e6,
        eval.kitsune_speedup(),
        100.0 * eval.kitsune_traffic_reduction()
    );
    // The full NeRF graph has concat skip links (multicast queue edges),
    // so it simulates rather than streams — the session says why.
    if let Some(reason) = session.not_streamable_reason() {
        println!("  (simulation-only: {reason})");
    }
    println!("\nper-subgraph (paper Fig 10):");
    for r in &eval.kitsune.regions {
        println!(
            "  {:<36} {:>2} ops  {:>6.1} us  speedup {:.2}x",
            r.name,
            r.n_ops,
            r.elapsed_s * 1e6,
            r.speedup()
        );
    }

    // Real numerics through the runtime backend, when artifacts exist.
    match ArtifactStore::load("artifacts") {
        Ok(store) => {
            let mut rng = Rng::new(7);
            let spec = store.spec("nerf_forward")?.clone();
            let inputs: Vec<Tensor> = spec
                .inputs
                .iter()
                .enumerate()
                .map(|(i, t)| {
                    if i == 0 {
                        let numel: usize = t.dims.iter().product();
                        Tensor {
                            dims: t.dims.clone(),
                            data: (0..numel).map(|_| rng.normal()).collect(),
                        }
                    } else {
                        rng.he_tensor(&t.dims)
                    }
                })
                .collect();
            let y_ref = store.run_f32("nerf_forward", &inputs)?;
            let y_pal = store.run_f32("nerf_forward_pallas", &inputs)?;
            let max_err = y_ref[0]
                .data
                .iter()
                .zip(&y_pal[0].data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            println!(
                "\nreal runtime execution: nerf_forward {:?} -> {:?}; pallas-kernel variant max |Δ| = {max_err:.2e}",
                spec.inputs[0].dims, y_ref[0].dims
            );
            anyhow::ensure!(max_err < 1e-4, "pallas path diverged from reference");
        }
        Err(e) => println!("\n(skipping real-artifact check: {e}; run `make artifacts`)"),
    }
    Ok(())
}
