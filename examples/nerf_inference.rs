//! NeRF inference — the paper's showcase application (§6.3): all forward
//! ops spatially fused, concats on SIMT pipes while GEMMs use the
//! TensorCores, 2.3x subgraph speedup and ~98% traffic reduction.
//!
//! Shows the per-sf-node breakdown the paper's Fig 10 plots, then (if
//! `make artifacts` has run) executes the *real* NeRF trunk through the
//! PJRT runtime to confirm the numerics the simulator is reasoning about.
//!
//! Run: `cargo run --release --example nerf_inference`

use kitsune::apps::nerf::{inference, NerfConfig};
use kitsune::report::evaluate_app;
use kitsune::runtime::{ArtifactStore, Rng, Tensor};
use kitsune::sim::GpuConfig;

fn main() -> anyhow::Result<()> {
    let cfg = GpuConfig::a100();
    let g = inference(&NerfConfig::default());
    let eval = evaluate_app("NERF", &g, &cfg)?;

    println!("NeRF inference on simulated {}:", cfg.name);
    println!(
        "  bulk-sync  {:>8.1} us   DRAM {:>7.1} MB",
        eval.bsp.sim.elapsed_s * 1e6,
        eval.bsp.sim.dram_bytes / 1e6
    );
    println!(
        "  vertical   {:>8.1} us   DRAM {:>7.1} MB   ({:.2}x)",
        eval.vertical.sim.elapsed_s * 1e6,
        eval.vertical.sim.dram_bytes / 1e6,
        eval.vertical_speedup()
    );
    println!(
        "  kitsune    {:>8.1} us   DRAM {:>7.1} MB   ({:.2}x, traffic -{:.1}%)",
        eval.kitsune.sim.elapsed_s * 1e6,
        eval.kitsune.sim.dram_bytes / 1e6,
        eval.kitsune_speedup(),
        100.0 * eval.kitsune_traffic_reduction()
    );
    println!("\nper-subgraph (paper Fig 10):");
    for r in &eval.kitsune.regions {
        println!(
            "  {:<36} {:>2} ops  {:>6.1} us  speedup {:.2}x",
            r.name,
            r.n_ops,
            r.elapsed_s * 1e6,
            r.speedup()
        );
    }

    // Real numerics through PJRT, when artifacts exist.
    match ArtifactStore::load("artifacts") {
        Ok(store) => {
            let mut rng = Rng::new(7);
            let spec = store.spec("nerf_forward")?.clone();
            let inputs: Vec<Tensor> = spec
                .inputs
                .iter()
                .enumerate()
                .map(|(i, t)| {
                    if i == 0 {
                        let numel: usize = t.dims.iter().product();
                        Tensor {
                            dims: t.dims.clone(),
                            data: (0..numel).map(|_| rng.normal()).collect(),
                        }
                    } else {
                        rng.he_tensor(&t.dims)
                    }
                })
                .collect();
            let y_ref = store.run_f32("nerf_forward", &inputs)?;
            let y_pal = store.run_f32("nerf_forward_pallas", &inputs)?;
            let max_err = y_ref[0]
                .data
                .iter()
                .zip(&y_pal[0].data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            println!(
                "\nreal PJRT execution: nerf_forward {:?} -> {:?}; pallas-kernel variant max |Δ| = {max_err:.2e}",
                spec.inputs[0].dims, y_ref[0].dims
            );
            anyhow::ensure!(max_err < 1e-4, "pallas path diverged from reference");
        }
        Err(e) => println!("\n(skipping real PJRT check: {e}; run `make artifacts`)"),
    }
    Ok(())
}
