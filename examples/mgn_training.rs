//! MeshGraphNets training — the paper's training story (§6.4): the
//! backward pass contains batch-dimension gradient reductions (Fig 2(b))
//! and activation-grad multicast to paired gradient GEMMs (Fig 2(c));
//! Kitsune's split reductions and spatial fusion give larger wins than
//! inference, while gather/scatter aggregations stay bulk-sync.
//!
//! Runs through the `kitsune::session` façade: `.app("MGN").training(true)`
//! resolves the training-suite graph, compiles once, and simulates.
//!
//! MGN is the documented *fallback* path of `kitsune::train`: its
//! gather/scatter aggregations are §5.1-excluded, so the real streaming
//! training pipeline refuses the graph with a typed reason naming the
//! offending op, and evaluation stays on the simulator (dense apps —
//! NeRF, DLRM's MLPs — take the real pipeline instead; see
//! `examples/e2e_train.rs`).
//!
//! Run: `cargo run --release --example mgn_training`

use kitsune::graph::{OpKind, ReduceAxis};
use kitsune::session::Session;

fn main() -> anyhow::Result<()> {
    let session = Session::builder().app("MGN").training(true).build()?;
    let g = session.graph().expect("app session has a graph");

    // The real training pipeline is unavailable here — show the typed
    // reason (it names the concrete op) and fall back to simulation.
    assert!(!session.is_trainable());
    match session.trainer() {
        Err(e) => println!("real training pipeline unavailable: {e:#}\n"),
        Ok(_) => anyhow::bail!("MGN training unexpectedly streamed"),
    }
    let bwd_start = g.backward_start.unwrap();
    let n_reduces = g
        .compute_nodes()
        .filter(|n| matches!(n.op, OpKind::Reduce { axis: ReduceAxis::Batch, .. }))
        .count();
    println!(
        "MGN training graph: {} ops ({} forward, {} backward+opt), {} batch-grad reductions",
        g.n_compute_ops(),
        g.nodes()[..bwd_start].iter().filter(|n| n.op.is_compute()).count(),
        g.nodes()[bwd_start..].iter().filter(|n| n.op.is_compute()).count(),
        n_reduces
    );

    let eval = session.simulate()?;
    println!("\nend-to-end (paper Fig 14):");
    println!("  bulk-sync {:>9.1} us", eval.bsp.sim.elapsed_s * 1e6);
    println!(
        "  vertical  {:>9.1} us  ({:.2}x — forward-only fusion)",
        eval.vertical.sim.elapsed_s * 1e6,
        eval.vertical_speedup()
    );
    println!(
        "  kitsune   {:>9.1} us  ({:.2}x, traffic -{:.1}%)",
        eval.kitsune.sim.elapsed_s * 1e6,
        eval.kitsune_speedup(),
        100.0 * eval.kitsune_traffic_reduction()
    );

    println!("\nper-subgraph, fwd/bwd split (paper Fig 12):");
    let (mut fwd, mut bwd) = (Vec::new(), Vec::new());
    for r in &eval.kitsune.regions {
        if r.backward {
            bwd.push(r.speedup());
        } else {
            fwd.push(r.speedup());
        }
        println!(
            "  {:<40} {} {:>2} ops  {:.2}x",
            r.name,
            if r.backward { "bwd" } else { "fwd" },
            r.n_ops,
            r.speedup()
        );
    }
    let gm = |v: &[f64]| kitsune::exec::geomean(v);
    println!("\n  forward geomean {:.2}x | backward geomean {:.2}x", gm(&fwd), gm(&bwd));
    println!(
        "  (training benefits more: parallelized reductions vs the parallelism-limited baseline — paper §6.4)"
    );
    Ok(())
}
