//! End-to-end validation (DESIGN.md E2E row): train the NeRF-class MLP
//! for a few hundred steps on synthetic data, with every training step
//! executing as a *real* AOT-compiled XLA `train_step` artifact through
//! the PJRT runtime — Python never runs. The loss curve is logged and
//! must descend; the final state is sanity-checked against a held-out
//! batch. Results are recorded in EXPERIMENTS.md.
//!
//! Requires `make artifacts` (skips cleanly without). Run:
//! `cargo run --release --example e2e_train -- [steps]`

use kitsune::runtime::{Rng, RuntimeError, Tensor};
use kitsune::session::Session;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(300);
    // The session façade also fronts AOT artifact access: an
    // artifacts-only build loads the store (typed skip when absent).
    let session = match Session::builder().artifacts("artifacts").build() {
        Ok(s) => s,
        Err(e) if matches!(
            e.downcast_ref::<RuntimeError>(),
            Some(RuntimeError::ArtifactsMissing { .. })
        ) =>
        {
            println!("skipping e2e training: {e}");
            return Ok(());
        }
        Err(e) => return Err(e),
    };
    let store = session.artifacts().expect("artifacts session has a store");
    let spec = store.spec("train_step")?.clone();
    println!(
        "train_step artifact: {} inputs -> {} outputs on {}",
        spec.inputs.len(),
        spec.n_outputs,
        store.platform()
    );

    // Synthetic regression task: y = sigmoid(x @ T) for a fixed random
    // teacher T — learnable by the student MLP, so the loss must fall.
    let mut rng = Rng::new(0xA11CE);
    let x_dims = spec.inputs[0].dims.clone(); // [batch, 60]
    let y_dims = spec.inputs[1].dims.clone(); // [batch, 3]
    let (batch, in_dim) = (x_dims[0], x_dims[1]);
    let out_dim = y_dims[1];
    let teacher: Vec<f32> = (0..in_dim * out_dim).map(|_| rng.normal() * 0.3).collect();
    let make_batch = |rng: &mut Rng| -> (Tensor, Tensor) {
        let x: Vec<f32> = (0..batch * in_dim).map(|_| rng.normal()).collect();
        let mut y = vec![0.0f32; batch * out_dim];
        for r in 0..batch {
            for c in 0..out_dim {
                let mut acc = 0.0;
                for k in 0..in_dim {
                    acc += x[r * in_dim + k] * teacher[k * out_dim + c];
                }
                y[r * out_dim + c] = 1.0 / (1.0 + (-acc).exp());
            }
        }
        (
            Tensor::new(x_dims.clone(), x).unwrap(),
            Tensor::new(y_dims.clone(), y).unwrap(),
        )
    };

    // He-initialized parameters (same layout as model.PARAM_SHAPES).
    let mut params: Vec<Tensor> =
        spec.inputs[2..].iter().map(|t| rng.he_tensor(&t.dims)).collect();
    let n_params: usize = params.iter().map(|p| p.data.len()).sum();
    println!("model: {n_params} parameters, batch {batch}, {steps} steps\n");

    let t0 = Instant::now();
    let mut first_loss = f32::NAN;
    let mut last_loss = f32::NAN;
    for step in 0..steps {
        let (x, y) = make_batch(&mut rng);
        let mut args = Vec::with_capacity(2 + params.len());
        args.push(x);
        args.push(y);
        args.extend(params.iter().cloned());
        let mut outs = store.run_f32("train_step", &args)?;
        let loss = outs.remove(0).scalar_value();
        params = outs;
        if step == 0 {
            first_loss = loss;
        }
        last_loss = loss;
        if step % 25 == 0 || step + 1 == steps {
            println!("step {step:>4}  loss {loss:.6}");
        }
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}");
    }
    let elapsed = t0.elapsed().as_secs_f64();
    println!(
        "\ntrained {steps} steps in {elapsed:.1}s ({:.1} steps/s, {:.2} ms/step)",
        steps as f64 / elapsed,
        1e3 * elapsed / steps as f64
    );
    println!("loss: {first_loss:.6} -> {last_loss:.6} ({:.1}% of initial)", 100.0 * last_loss / first_loss);
    anyhow::ensure!(
        last_loss < 0.8 * first_loss,
        "training failed to converge: {first_loss} -> {last_loss}"
    );
    println!("e2e training OK — all layers compose (Pallas->JAX->HLO->PJRT->Rust).");
    Ok(())
}
