//! End-to-end training through `kitsune::train`: a NeRF-class model
//! trains on the *real* streaming DAG pipeline — forward, backward,
//! loss, and gradient taps execute as persistent pipeline stages with
//! multicast and skip-link queues, gradients are averaged per
//! microbatch, and an Adam optimizer (the configurable replacement for
//! the old baked-in-LR `train_step` entry) updates the shared
//! parameters between steps. The loss curve is logged and must descend;
//! the first step is cross-checked bitwise against the serial oracle.
//!
//! Run: `cargo run --release --example e2e_train -- [steps]`

use kitsune::apps::nerf;
use kitsune::session::Session;
use kitsune::train::{serial_step, split_batch, OptimizerKind};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(120);

    // A small NeRF with the skip concat in play — the shape whose
    // backward pass needs the multicast/skip-link queues.
    let cfg = nerf::NerfConfig {
        batch: 256,
        pos_enc: 12,
        dir_enc: 8,
        hidden: 32,
        depth: 4,
        skip_at: 2,
    };
    let session = Session::builder().graph(nerf::training(&cfg)).tile_rows(32).build()?;
    let plan = session.train_plan().expect("NeRF training lowers to the DAG pipeline");
    let n_params: usize = plan.params.iter().map(|p| p.init.numel()).sum();
    println!(
        "training pipeline: {} stages, {} queue edges ({} skip links, {} multicast ports)",
        plan.pipeline.stages.len(),
        plan.pipeline.edges.len(),
        plan.n_skip_links(),
        plan.n_multicasts(),
    );
    println!(
        "model: {n_params} parameters, batch {} ({} tiles x {} rows), {steps} steps\n",
        plan.batch_rows,
        plan.n_tiles(),
        plan.tile_rows,
    );

    // Fixed synthetic batch (memorization task) and the Trainer loop.
    let batch = session.make_train_batch(0xA11CE)?;
    let mut trainer = session.trainer_with(OptimizerKind::adam(3e-3))?;

    // Step 1 sanity: the pipeline must agree with the serial oracle
    // bitwise before we trust the curve.
    let params0: Vec<_> = trainer.params().into_iter().map(|(_, t)| t).collect();
    let oracle = serial_step(plan, &params0, &split_batch(plan, &batch)?)?;

    let t0 = Instant::now();
    let mut first_loss = f32::NAN;
    let mut last_loss = f32::NAN;
    for step in 0..steps {
        let stats = trainer.step(&batch)?;
        if step == 0 {
            first_loss = stats.loss;
            anyhow::ensure!(
                stats.loss.to_bits() == oracle.loss.to_bits(),
                "pipeline loss {} != serial oracle {}",
                stats.loss,
                oracle.loss
            );
        }
        last_loss = stats.loss;
        if step % 20 == 0 || step + 1 == steps {
            println!("step {step:>4}  loss {:.6}", stats.loss);
        }
        anyhow::ensure!(stats.loss.is_finite(), "loss diverged at step {step}");
    }
    let elapsed = t0.elapsed().as_secs_f64();
    println!(
        "\ntrained {steps} steps in {elapsed:.1}s ({:.1} steps/s, {:.2} ms/step)",
        steps as f64 / elapsed,
        1e3 * elapsed / steps as f64
    );
    println!(
        "loss: {first_loss:.6} -> {last_loss:.6} ({:.1}% of initial)",
        100.0 * last_loss / first_loss
    );
    anyhow::ensure!(
        last_loss < 0.9 * first_loss,
        "training failed to converge: {first_loss} -> {last_loss}"
    );
    session.shutdown();
    println!("e2e training OK — gradients streamed through the dataflow pipeline.");
    Ok(())
}
