//! Serving through the session façade: one *warm* spatial pipeline —
//! stage workers and ring queues stood up once at `build()` — serving
//! batched requests from many concurrent client threads, with per-ticket
//! latency and aggregate throughput reporting. This is the paper's Fig 6
//! lifecycle (`cudaPipelineCreate` → `AddKernel` → launch once, then
//! stream) running for real at host level, and the serving shape an
//! LLM deployment needs: setup amortized across the request stream.
//!
//! The decode-phase caveat (paper LL-TOK) still applies: tiny tiles make
//! the queue-hop overhead visible, so streaming buys little on
//! token-at-a-time shapes — matching the ~0% traffic-reduction row of
//! Table 2. Try `--rows 1` equivalent by lowering the tile rows below.
//!
//! Run: `cargo run --release --example llama_serving -- [n_requests]`

use kitsune::session::{nerf_trunk_graph, Session};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let n_requests: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(96);
    let clients = 4usize;

    // Build once: compile -> lower -> persistent worker pool.
    let session = Session::builder()
        .graph(nerf_trunk_graph(8192, 60, 64, 3))
        .tile_rows(128)
        .workers(2)
        .build()?;
    let stages = session.pipeline().expect("trunk streams").stages.len();
    println!(
        "warm session: {stages}-stage pipeline, {} threads (all spawned at build); serving {n_requests} requests (128 rows each)",
        session.threads_spawned()
    );

    // Bulk-sync analog: requests processed one at a time, stage by stage.
    let inputs = session.make_tiles(n_requests, 0xFEED)?;
    let serial = session.run_serial(inputs.clone())?;
    println!(
        "\nserial    : {:>8.1} ms total  {:>7.1} req/s  {:>7.2} ms/req",
        serial.elapsed_s * 1e3,
        serial.tiles_per_sec(),
        serial.elapsed_s * 1e3 / n_requests as f64
    );

    // Single client through the warm pipeline.
    let run = session.run(inputs)?;
    println!(
        "dataflow  : {:>8.1} ms total  {:>7.1} req/s  speedup {:.2}x",
        run.elapsed_s * 1e3,
        run.tiles_per_sec(),
        serial.elapsed_s / run.elapsed_s.max(1e-12)
    );

    // Verify results identical to serial execution.
    let max_err = run
        .outputs
        .iter()
        .zip(&serial.outputs)
        .flat_map(|(a, b)| a.data.iter().zip(&b.data).map(|(x, y)| (x - y).abs()))
        .fold(0.0f32, f32::max);
    anyhow::ensure!(max_err < 1e-5, "pipeline diverged from serial: {max_err}");

    // Many clients, one warm pipeline: tickets interleave through the
    // same stage workers; each caller still gets its outputs in order.
    let threads_before = session.threads_spawned();
    let per_client = (n_requests / clients).max(1);
    let t0 = Instant::now();
    let total: usize = std::thread::scope(|scope| -> anyhow::Result<usize> {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let session = &session;
                scope.spawn(move || -> anyhow::Result<(usize, f64)> {
                    let batch = session.make_tiles(per_client, 0xBEEF + c as u64)?;
                    let out = session.submit(batch)?.wait()?;
                    Ok((out.outputs.len(), out.elapsed_s))
                })
            })
            .collect();
        let mut total = 0;
        for (c, h) in handles.into_iter().enumerate() {
            let (n, elapsed) = h.join().map_err(|_| anyhow::anyhow!("client panicked"))??;
            println!("  client {c}: {n} requests in {:.1} ms", elapsed * 1e3);
            total += n;
        }
        Ok(total)
    })?;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "concurrent: {clients} clients x {per_client} req  {:>8.1} ms wall  {:>7.1} req/s aggregate",
        wall * 1e3,
        total as f64 / wall.max(1e-12)
    );
    anyhow::ensure!(
        session.threads_spawned() == threads_before,
        "submit must never spawn new stage threads"
    );

    for m in &session.metrics() {
        println!(
            "  {:<8} [{:?}] x{}  busy {:>7.1} ms  wait {:>7.1} ms  util {:>3.0}%",
            m.name,
            m.class,
            m.workers,
            m.busy_s * 1e3,
            m.wait_s * 1e3,
            m.utilization() * 100.0
        );
    }
    println!("\noutputs bit-match serial execution (max |Δ| = {max_err:.1e})");
    session.shutdown();
    Ok(())
}
