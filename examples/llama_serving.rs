//! Serving with the real coordinator: batched requests streamed through
//! a spatial pipeline of AOT-compiled XLA stage kernels connected by the
//! §4.1 ring queues, with per-request latency and throughput reporting —
//! the paper's execution model running for real at host level.
//!
//! Also shows the decode-phase story (paper LL-TOK): tiny tiles make the
//! queue-hop overhead visible, so streaming buys little — matching the
//! ~0% traffic-reduction row of Table 2.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example llama_serving -- [n_requests]`

use kitsune::coordinator::cli::{build_nerf_pipeline, input_tiles};
use kitsune::coordinator::{run_serial, run_streaming};
use kitsune::runtime::ArtifactStore;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let n_requests: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(96);
    let store = ArtifactStore::load("artifacts")?;
    println!("platform {}; serving {} batched requests (128 rows each)", store.platform(), n_requests);

    let pipeline = build_nerf_pipeline(&store, 2)?;
    let inputs = input_tiles(&store, "stage_trunk0", n_requests)?;

    // Bulk-sync analog: requests processed one at a time, stage by stage.
    let serial = run_serial(&store, &pipeline, inputs.clone())?;
    println!(
        "\nserial    : {:>8.1} ms total  {:>7.1} req/s  {:>7.2} ms/req",
        serial.elapsed_s * 1e3,
        serial.tiles_per_sec(),
        serial.elapsed_s * 1e3 / n_requests as f64
    );

    // Spatial pipeline: co-resident stages, queue backpressure.
    let t0 = Instant::now();
    let run = run_streaming(&store, &pipeline, inputs)?;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "dataflow  : {:>8.1} ms total  {:>7.1} req/s  speedup {:.2}x",
        run.elapsed_s * 1e3,
        run.tiles_per_sec(),
        serial.elapsed_s / run.elapsed_s
    );
    for m in &run.metrics {
        println!(
            "  {:<8} [{:?}] x{}  busy {:>7.1} ms  wait {:>7.1} ms  util {:>3.0}%",
            m.name,
            m.class,
            m.workers,
            m.busy_s * 1e3,
            m.wait_s * 1e3,
            m.utilization() * 100.0
        );
    }

    // Verify results identical to serial execution.
    let max_err = run
        .outputs
        .iter()
        .zip(&serial.outputs)
        .flat_map(|(a, b)| a.data.iter().zip(&b.data).map(|(x, y)| (x - y).abs()))
        .fold(0.0f32, f32::max);
    anyhow::ensure!(max_err < 1e-5, "pipeline diverged from serial: {max_err}");
    println!("\noutputs bit-match serial execution (max |Δ| = {max_err:.1e}); wall {wall:.2}s");
    Ok(())
}
