//! Fault-injection determinism driver: run a warm pipeline under the
//! chaos spec in `KITSUNE_FAULT` and *verify* the typed outcome, exiting
//! non-zero on any deviation.
//!
//! CI runs this example many times per spec — same spec, same typed
//! failure, every run — which is the contract that makes `KITSUNE_FAULT`
//! a debugging tool rather than a flake generator:
//!
//! ```sh
//! KITSUNE_FAULT="panic:stage=2:tile=3"  cargo run --release --example fault_demo
//! KITSUNE_FAULT="queue_close:edge=1"    cargo run --release --example fault_demo
//! KITSUNE_FAULT="nan:loss:step=0"       cargo run --release --example fault_demo
//! ```
//!
//! With `KITSUNE_FAULT` unset the demo runs the same pipelines fault-free
//! (and asserts that they succeed), so the same binary doubles as a
//! no-fault smoke test.

use kitsune::fault::{FailureCause, FaultPlan, FaultSpec, Health};
use kitsune::runtime::RuntimeError;
use kitsune::session::{nerf_trunk_graph, Session, Ticket};
use kitsune::train::StepOutcome;
use std::time::Duration;

/// Bounded wait: a hung ticket is exactly the failure mode this driver
/// exists to catch, so it must terminate the process, not stall CI.
fn wait_bounded(t: Ticket) -> anyhow::Result<kitsune::session::BatchResult> {
    match t.wait_timeout(Duration::from_secs(60)) {
        Ok(r) => r,
        Err(_) => {
            eprintln!("FAIL: ticket did not resolve within 60s (hung ticket)");
            std::process::exit(2);
        }
    }
}

fn stage_failure(err: &anyhow::Error) -> kitsune::fault::StageFailure {
    match err.downcast_ref::<RuntimeError>() {
        Some(RuntimeError::StageFailed(f)) => f.clone(),
        _ => {
            eprintln!("FAIL: untyped error (expected RuntimeError::StageFailed): {err:#}");
            std::process::exit(2);
        }
    }
}

/// The parsed spec this process is expected to reproduce. The session
/// itself re-parses `KITSUNE_FAULT` through [`FaultPlan::from_env`]; this
/// copy only tells the driver what outcome to demand.
fn expected_specs() -> Vec<FaultSpec> {
    let raw = match std::env::var("KITSUNE_FAULT") {
        Ok(raw) => raw,
        Err(_) => return Vec::new(),
    };
    let plan = match FaultPlan::parse(&raw) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("FAIL: bad KITSUNE_FAULT {raw:?}: {msg}");
            std::process::exit(2);
        }
    };
    // Drain the private armed set through the public take_* surface.
    let mut specs = Vec::new();
    for edge in plan.take_queue_closes() {
        specs.push(FaultSpec::QueueClose { edge });
    }
    for stage in 0..64usize {
        for tile in 0..64u64 {
            if plan.take_panic(stage, tile) {
                specs.push(FaultSpec::Panic { stage, tile });
            }
        }
    }
    for step in 0..64u64 {
        if plan.take_nan_loss(step) {
            specs.push(FaultSpec::NanLoss { step });
        }
        if plan.take_nan_grad(step) {
            specs.push(FaultSpec::NanGrad { step });
        }
    }
    specs
}

/// Drive the inference pipeline: `n` single-tile tickets, then report
/// which (if any) failed and how.
fn run_inference(expect: &[FaultSpec]) -> anyhow::Result<()> {
    let session = Session::builder()
        .graph(nerf_trunk_graph(64, 6, 16, 3))
        .tile_rows(4)
        .workers(1)
        .build()?;
    let n_stages = session.pipeline().expect("trunk streams").stages.len();
    let n_tiles = 8usize;
    let structural = expect.iter().any(|s| matches!(s, FaultSpec::QueueClose { .. }));
    // A panic spec outside this demo's pipeline/tile range never strikes;
    // treat it as a clean run rather than demanding a failure.
    let panic_at = expect.iter().find_map(|s| match s {
        FaultSpec::Panic { stage, tile } if *stage < n_stages && *tile < n_tiles as u64 => {
            Some((*stage, *tile))
        }
        _ => None,
    });
    let tiles = session.make_tiles(n_tiles, 0xFA17)?;
    let tickets: Vec<Ticket> =
        tiles.into_iter().map(|t| session.submit(vec![t])).collect::<Result<_, _>>()?;
    let mut failures = Vec::new();
    for (i, ticket) in tickets.into_iter().enumerate() {
        match wait_bounded(ticket) {
            Ok(out) => assert_eq!(out.outputs.len(), 1),
            Err(e) => failures.push((i, stage_failure(&e))),
        }
    }

    if structural {
        // Every ticket behind the dead edge resolves typed; none complete
        // past it, none hang.
        assert!(
            matches!(session.health(), Health::Failed { .. }),
            "queue_close must fail the pipeline: {:?}",
            session.health()
        );
        assert!(!failures.is_empty(), "queue_close must fail tickets");
        for (i, f) in &failures {
            assert!(
                matches!(f.cause, FailureCause::QueueClosed),
                "ticket {i}: expected QueueClosed, got {f}"
            );
        }
        println!(
            "ok: queue_close failed {}/{} tickets typed, pipeline Failed, none hung",
            failures.len(),
            n_tiles
        );
    } else if let Some((stage, tile)) = panic_at {
        assert_eq!(
            failures.len(),
            1,
            "exactly the afflicted ticket fails (got {failures:?})"
        );
        let (i, f) = &failures[0];
        assert_eq!(*i as u64, tile, "tile ordinal is deterministic: {f}");
        assert_eq!(f.stage_index, Some(stage), "{f}");
        assert!(matches!(&f.cause, FailureCause::Panic(m) if m.contains("injected fault")), "{f}");
        // Supervised restart: the pipeline returns to Healthy.
        let t0 = std::time::Instant::now();
        while !session.health().is_healthy() {
            if t0.elapsed() > Duration::from_secs(10) {
                eprintln!("FAIL: health stuck at {:?}", session.health());
                std::process::exit(2);
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        println!("ok: panic at stage {stage} tile {tile} failed 1/{n_tiles} tickets, recovered");
    } else {
        assert!(failures.is_empty(), "fault-free run must not fail: {failures:?}");
        println!("ok: {n_tiles}/{n_tiles} tickets completed fault-free");
    }
    session.shutdown();
    Ok(())
}

/// Drive two training steps so `nan:loss:step=0/1` and `nan:grad` specs
/// have a surface to strike.
fn run_training(expect: &[FaultSpec]) -> anyhow::Result<()> {
    let nan_step = expect.iter().find_map(|s| match s {
        FaultSpec::NanLoss { step } | FaultSpec::NanGrad { step } => Some(*step),
        _ => None,
    });
    let Some(nan_step) = nan_step else { return Ok(()) };
    let g = kitsune::apps::nerf::training(&kitsune::apps::nerf::NerfConfig {
        batch: 64,
        pos_enc: 8,
        dir_enc: 4,
        hidden: 16,
        depth: 3,
        skip_at: 1,
    });
    let session = Session::builder().graph(g).tile_rows(16).build()?;
    let mut trainer = session.trainer()?;
    let batch = session.make_train_batch(7)?;
    for step in 0..=nan_step + 1 {
        let stats = trainer.step(&batch)?;
        if step == nan_step {
            assert!(
                matches!(stats.outcome, StepOutcome::Skipped { .. }),
                "step {step} must be skipped by the non-finite guard: {:?}",
                stats.outcome
            );
        } else {
            assert_eq!(stats.outcome, StepOutcome::Applied, "step {step}");
            assert!(stats.loss.is_finite());
        }
    }
    println!("ok: training skipped step {nan_step}, neighbors applied");
    session.shutdown();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let expect = expected_specs();
    match std::env::var("KITSUNE_FAULT") {
        Ok(raw) => println!("fault_demo: KITSUNE_FAULT={raw:?} -> {expect:?}"),
        Err(_) => println!("fault_demo: no fault armed (clean smoke run)"),
    }
    run_inference(&expect)?;
    run_training(&expect)?;
    println!("fault_demo: PASS");
    Ok(())
}
