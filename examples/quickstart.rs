//! Quickstart: the whole Kitsune stack in ~70 lines, through the one
//! public façade — `kitsune::session`.
//!
//! Builds a transformer-FFN-style graph (the paper's Fig 2(a) pattern),
//! and `Session::builder().graph(g).build()` does the rest: subgraph
//! selection, pipeline design (Algorithm 1), ILP load balancing
//! (Algorithm 2), and lowering the compiled plan to a real spatial
//! pipeline. `simulate()` compares bulk-synchronous, vertical-fusion and
//! Kitsune dataflow on the simulated A100; `submit()` then streams real
//! tiles through the same compiled plan's warm worker pool.
//!
//! Run: `cargo run --release --example quickstart`

use kitsune::graph::{EwKind, GraphBuilder, GraphKind};
use kitsune::session::{nerf_trunk_graph, Session};

fn main() -> anyhow::Result<()> {
    // 1. Author a model graph (what PyTorch+Dynamo provides in the paper).
    let mut b = GraphBuilder::new("ffn", GraphKind::Inference);
    let x = b.input(&[4096, 1024], "x");
    b.mlp(x, &[4096, 4096, 1024], EwKind::Gelu, true, "ffn");
    let g = b.finish();
    println!("graph: {} ops, {:.1} GFLOP", g.n_compute_ops(), g.total_flops() / 1e9);

    // 2. One façade from graph to execution: build() compiles the graph
    //    (cold here — the simulator answers the timing questions).
    let session = Session::builder().graph(g).warm(false).build()?;
    let compiled = session.compiled().expect("session compiles at build");
    println!(
        "compiler: {} sf-node(s), coverage {:.0}%",
        compiled.pipelines.len(),
        100.0 * compiled.selection.coverage(session.graph().unwrap())
    );
    for lp in &compiled.pipelines {
        println!(
            "  {}: {} stages, {} queues, CTA allocation {:?}",
            lp.desc.name,
            lp.desc.stages.len(),
            lp.desc.queues.len(),
            lp.balanced.alloc
        );
    }

    // 3. Simulate under all three execution models (paper §6).
    let eval = session.simulate()?;
    println!("\n{:<14} {:>10} {:>12} {:>10}", "mode", "time", "DRAM traffic", "speedup");
    for r in [&eval.bsp, &eval.vertical, &eval.kitsune] {
        println!(
            "{:<14} {:>8.1}us {:>10.1}MB {:>9.2}x",
            r.mode.to_string(),
            r.sim.elapsed_s * 1e6,
            r.sim.dram_bytes / 1e6,
            eval.bsp.sim.elapsed_s / r.sim.elapsed_s
        );
    }
    println!(
        "\nKitsune: {:.2}x speedup, {:.0}% DRAM traffic reduction, {:.0}% of busy SM-time paired",
        eval.kitsune_speedup(),
        100.0 * eval.kitsune_traffic_reduction(),
        100.0 * eval.kitsune.sim.paired_frac
    );

    // 4. The same API executes for real: a warm session streams tiles
    //    through the lowered plan's persistent stage workers.
    let real = Session::builder()
        .graph(nerf_trunk_graph(1024, 60, 64, 3))
        .tile_rows(64)
        .build()?;
    let out = real.submit(real.make_tiles(16, 7)?)?.wait()?;
    println!(
        "\nreal execution via the same façade: {} tiles through {} warm stages, {:.0} tiles/s",
        out.outputs.len(),
        real.pipeline().expect("trunk streams").stages.len(),
        out.tiles_per_sec()
    );
    Ok(())
}
