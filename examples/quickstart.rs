//! Quickstart: the whole Kitsune stack in ~60 lines.
//!
//! Builds a transformer-FFN-style graph (the paper's Fig 2(a) pattern),
//! compiles it — subgraph selection, pipeline design (Algorithm 1), ILP
//! load balancing (Algorithm 2) — and compares bulk-synchronous,
//! vertical-fusion, and Kitsune dataflow execution on the simulated A100.
//!
//! Run: `cargo run --release --example quickstart`

use kitsune::compiler::{compile, SelectOptions};
use kitsune::exec::{run_bsp_detailed, run_dataflow, run_vertical};
use kitsune::graph::{EwKind, GraphBuilder, GraphKind};
use kitsune::sim::{Engine, GpuConfig, SchedPolicy};

fn main() -> anyhow::Result<()> {
    // 1. Author a model graph (what PyTorch+Dynamo provides in the paper).
    let mut b = GraphBuilder::new("ffn", GraphKind::Inference);
    let x = b.input(&[4096, 1024], "x");
    b.mlp(x, &[4096, 4096, 1024], EwKind::Gelu, true, "ffn");
    let g = b.finish();
    println!("graph: {} ops, {:.1} GFLOP", g.n_compute_ops(), g.total_flops() / 1e9);

    // 2. Compile for dataflow execution.
    let cfg = GpuConfig::a100();
    let app = compile(&g, &cfg, &SelectOptions::default())?;
    println!(
        "compiler: {} sf-node(s), coverage {:.0}%",
        app.pipelines.len(),
        100.0 * app.selection.coverage(&g)
    );
    for lp in &app.pipelines {
        println!(
            "  {}: {} stages, {} queues, CTA allocation {:?}",
            lp.desc.name,
            lp.desc.stages.len(),
            lp.desc.queues.len(),
            lp.balanced.alloc
        );
    }

    // 3. Execute under all three models.
    let bsp_engine = Engine::new(cfg.clone(), SchedPolicy::RoundRobin);
    let kitsune_engine = Engine::new(cfg, SchedPolicy::DualArbiter);
    let (bsp, per_node) = run_bsp_detailed(&g, &bsp_engine)?;
    let vf = run_vertical(&g, &bsp_engine, &per_node)?;
    let df = run_dataflow(&g, &app, &kitsune_engine, &per_node)?;

    println!("\n{:<14} {:>10} {:>12} {:>10}", "mode", "time", "DRAM traffic", "speedup");
    for r in [&bsp, &vf, &df] {
        println!(
            "{:<14} {:>8.1}us {:>10.1}MB {:>9.2}x",
            r.mode.to_string(),
            r.sim.elapsed_s * 1e6,
            r.sim.dram_bytes / 1e6,
            bsp.sim.elapsed_s / r.sim.elapsed_s
        );
    }
    println!(
        "\nKitsune: {:.2}x speedup, {:.0}% DRAM traffic reduction, {:.0}% of busy SM-time paired",
        df.speedup_over(&bsp),
        100.0 * df.traffic_reduction_vs(&bsp),
        100.0 * df.sim.paired_frac
    );
    Ok(())
}
